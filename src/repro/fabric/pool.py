"""ServicePool — client-side routed calls to a named service.

The pool resolves a service name through the registry to N live replicas
and routes every call through a pluggable balancer, adding the
reliability layer a single hard-coded URI cannot give:

  * **cached views, refreshed by epoch** — a cheap ``fab.epoch`` poll
    (rate-limited to ``refresh_interval``) detects membership changes;
    the full ``fab.resolve`` only runs on an epoch bump or after a
    failure, so the steady-state per-call overhead is zero RPCs;
  * **locality-tiered resolution** — each replica's address set resolves
    to the cheapest reachable transport (self > sm > tcp, via the same
    tier order as ``na/multi.py``); a tier that fails at runtime (stale
    sm segment after a replica restart) is **demoted** in the cached
    view and the call transparently falls back to the next tier;
  * **deadlines + budgeted retries + hedging** — every call runs under
    :func:`~repro.fabric.policy.call_with_budget`; per-attempt transport
    timeouts are clamped to the caller's deadline, retries use jittered
    exponential backoff and count against a fixed attempt budget which
    *includes* hedge requests, and the losing side of a hedge is
    canceled at the transport;
  * **credit-based flow control** — per-replica credit gates bound
    in-flight requests so a slow replica backpressures instead of
    queueing unboundedly, and gate occupancy feeds back into the
    balancer's load signal.  By default the gates are **adaptive**
    (:class:`~repro.fabric.flow.AdaptiveCreditGate`): each replica's
    limit is grown/shrunk AIMD-style from its observed completion
    latency, so fast replicas absorb more in-flight work and slow ones
    backpressure sooner — ``adaptive_credits=False`` restores the fixed
    ``credits_per_target`` behavior;
  * **deadline-aware admission** — the caller's remaining deadline
    budget rides the request header (``Engine.call_async(deadline=...)``
    → ``RequestHeader.budget_ms``); a server that cannot finish in time
    sheds with ``Ret.OVERLOAD``, which the pool treats as *retry on
    another replica, immediately* (no backoff — see
    ``RetryPolicy.fast_rets``);
  * **replicated control plane** — ``registry_uri`` may name the whole
    registry replica set (list, or one comma-separated string); the
    pool's :class:`~repro.fabric.registry.RegistryClient` sticks to the
    replica that last answered and rotates on dead-peer detection, so a
    registry-leader kill costs at most one failed control-plane RPC —
    never a data-path error (stale cached views keep routing, and the
    post-failover nonce change triggers a full resync).  The plane is
    *unified* (DESIGN.md §8): every quorum node mirrors the instance
    table and the membership table over one delta-gossip stream, so
    follower-served ``fab.resolve`` reads stay within one gossip round
    of the leaseholder even at very large instance counts — the pool's
    steady-state ``fab.epoch`` polls and full resolves are equally
    valid against any replica.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from ..core.executor import CallFuture, Engine, RemoteError
from ..core.na.base import SCHEME_TIERS
from ..core.na.multi import scheme_of as _scheme
from ..core.types import MercuryError, Ret
from ..telemetry import metrics as _metrics
from ..telemetry import trace as _trace
from .balancer import Balancer, make_balancer, prefer_instance
from .flow import AdaptiveCreditGate, CreditGate
from .policy import (BudgetExhausted, DeadlineExceeded, NonRetryable,
                     RetryPolicy, call_with_budget)
from .registry import RegistryClient  # noqa: F401  (re-exported surface)
from .sharding import registry_client_for

# errors worth retrying on another replica: the request may never have
# executed (or the transport lost the answer — or, for OVERLOAD, the
# target refused it untouched because it could not meet the deadline).
# Application faults (FAULT/NOENTRY/INVALID_ARG/...) are NOT retried:
# the handler ran.
_RETRYABLE = {Ret.TIMEOUT, Ret.DISCONNECT, Ret.AGAIN, Ret.NOMEM,
              Ret.CANCELED, Ret.PROTOCOL_ERROR, Ret.CHECKSUM_ERROR,
              Ret.OVERLOAD}
# transport-level failures that indicate the *resolved tier* (not the
# service) is bad — trigger tier demotion and a mark-down
_TIER_FAULTS = {Ret.DISCONNECT, Ret.PROTOCOL_ERROR}
# failures that are congestion signals for the adaptive credit gate: the
# replica (not the transport tier, not the application) is struggling
_CONGESTION = {Ret.TIMEOUT, Ret.AGAIN, Ret.OVERLOAD, Ret.DISCONNECT}

# unified metrics (docs/OPERATIONS.md §7): process-wide totals across
# every pool in this process, exported via fab.metrics
_M_CALLS = _metrics.counter("fabric.pool.calls")
_M_CALL_ERRORS = _metrics.counter("fabric.pool.call_errors")
_M_ATTEMPTS = _metrics.counter("fabric.pool.attempts")
_M_HEDGES = _metrics.counter("fabric.pool.hedges")
_M_CALL_MS = _metrics.histogram("fabric.pool.call_ms")


def _status_of(err: Optional[BaseException]) -> str:
    """Span status string for an attempt/call outcome."""
    if err is None:
        return "OK"
    ret = getattr(err, "ret", None)
    return ret.name if ret is not None else type(err).__name__


class PoolError(MercuryError):
    pass


def _tier_sorted(uris: Sequence[str]) -> List[str]:
    return sorted(uris, key=lambda u: SCHEME_TIERS.get(_scheme(u), 99))


class Replica:
    """The pool's cached view of one service instance: registry-reported
    state + local routing state (resolved tier, credit gate, stats).

    All mutable routing state (``addr``/``resolved_uri``/``bad_schemes``/
    ``down_until``) is guarded by one reentrant lock — ``demote``,
    ``reresolve`` and ``mark_down`` race freely from retry paths on
    different caller threads, and each transition must be atomic."""

    def __init__(self, iid: str, uris: Sequence[str], capacity: int,
                 load: float, gate: CreditGate):
        self.iid = iid
        self.uris = _tier_sorted(uris)
        self.capacity = capacity
        self.load = load
        self.gate = gate
        self.bad_schemes: set = set()  #: guarded-by _lock
        self.addr = None  #: guarded-by _lock
        self.resolved_uri: Optional[str] = None  #: guarded-by _lock
        self.down_until = 0.0  #: guarded-by _lock
        self.calls = 0  #: guarded-by _lock
        self.errors = 0  #: guarded-by _lock
        self.ema_latency = 0.0  #: guarded-by _lock
        # reentrant: demote/reresolve re-enter resolve() under the lock
        self._lock = threading.RLock()

    @property
    def tier(self) -> int:
        with self._lock:
            u = self.resolved_uri
        return SCHEME_TIERS.get(_scheme(u), 99) if u else 99

    def route(self) -> tuple:
        """Consistent (addr, resolved_uri) snapshot — a demote/reresolve
        racing an unlocked pair of reads could hand back the address of
        one tier labelled with the URI of another."""
        with self._lock:
            return self.addr, self.resolved_uri

    def resolve(self, engine: Engine) -> bool:
        """Resolve the cheapest non-demoted tier; False if unreachable."""
        with self._lock:
            for uri in self.uris:
                if _scheme(uri) in self.bad_schemes:
                    continue
                try:
                    self.addr = engine.lookup(uri)
                    self.resolved_uri = uri
                    return True
                except MercuryError:
                    continue
            self.addr = None
            self.resolved_uri = None
            return False

    def demote(self, engine: Engine) -> bool:
        """Demote the currently resolved tier (it failed at runtime) and
        re-resolve; True if a fallback tier exists."""
        with self._lock:
            if self.resolved_uri is None:
                return False
            self.bad_schemes.add(_scheme(self.resolved_uri))
            return self.resolve(engine)

    def reresolve(self, engine: Engine) -> bool:
        """Forget demotions and resolve from scratch — the recovery path
        for transient failures (a blip must not exclude a healthy replica
        forever; a tier that is still broken just demotes again)."""
        with self._lock:
            self.bad_schemes.clear()
            self.down_until = 0.0
            return self.resolve(engine)

    def mark_down(self, ttl: float) -> None:
        with self._lock:
            self.down_until = time.monotonic() + ttl

    @property
    def is_up(self) -> bool:
        with self._lock:
            return (self.addr is not None
                    and time.monotonic() >= self.down_until)

    def record(self, dt: Optional[float], ok: bool) -> None:
        with self._lock:
            self.calls += 1
            if not ok:
                self.errors += 1
            elif dt is not None:
                self.ema_latency = (0.2 * dt + 0.8 * self.ema_latency
                                    if self.ema_latency else dt)
        # feed the adaptive credit controller outside the routing lock
        # (the gate has its own lock; no nesting, no ordering constraint)
        if ok and dt is not None and isinstance(self.gate,
                                                AdaptiveCreditGate):
            self.gate.record_latency(dt)

    def penalize(self) -> None:
        """A congestion-class failure: multiplicative-decrease the
        adaptive gate (no-op on fixed gates)."""
        if isinstance(self.gate, AdaptiveCreditGate):
            self.gate.record_failure()

    def stat(self) -> dict:
        with self._lock:
            return {"iid": self.iid, "uri": self.resolved_uri,
                    "tier": _scheme(self.resolved_uri or "?"),
                    "capacity": self.capacity, "load": self.load,
                    "calls": self.calls, "errors": self.errors,
                    "ema_latency_ms": self.ema_latency * 1e3,
                    "up": self.is_up, **self.gate.stats()}


class ServicePool:
    """Resolve ``service`` via the registry and route calls across its
    replicas.  Thread-safe: many caller threads may ``call`` at once."""

    def __init__(self, engine: Engine, registry_uri, service: str,
                 balancer: Balancer | str = "locality",
                 policy: Optional[RetryPolicy] = None,
                 credits_per_target: int = 8,
                 adaptive_credits: bool = True,
                 credit_min: int = 1, credit_max: int = 64,
                 credit_target_latency: Optional[float] = None,
                 refresh_interval: float = 0.25,
                 load_refresh_interval: float = 1.0,
                 default_timeout: float = 30.0,
                 down_ttl: float = 2.0,
                 cache_ttl: Optional[float] = None):
        self.engine = engine
        self.service = service
        # short control-plane timeout: a dead registry must not stall the
        # data path (stale cached views keep routing).  registry_uri may
        # be the whole replica set; the client fails over between them.
        # The client-side read cache (DESIGN.md §9) collapses concurrent
        # refresh storms — hedged attempts and many caller threads all
        # force-refreshing at once singleflight into one fab.resolve —
        # and its TTL (default: half the refresh interval, so it never
        # adds more than one poll period of staleness) soaks up repeat
        # polls between ticks.  Correctness does not rest on the TTL:
        # every epoch bump or nonce change the client observes evicts.
        if cache_ttl is None:
            cache_ttl = refresh_interval / 2
        # A sharded spec ('|'-separated shard quorums, DESIGN.md §12)
        # binds the pool to the one shard that owns this service name —
        # the epoch-poll/token refresh below is per-shard by design.
        self.registry = registry_client_for(engine, registry_uri,
                                            service=service, timeout=2.0,
                                            cache_ttl=cache_ttl)
        self.balancer = make_balancer(balancer)
        self.policy = policy or RetryPolicy()
        self.credits_per_target = credits_per_target
        self.adaptive_credits = adaptive_credits
        self.credit_min = credit_min
        self.credit_max = credit_max
        self.credit_target_latency = credit_target_latency
        self.refresh_interval = refresh_interval
        # piggybacked load/capacity reports do not bump the epoch, so a
        # pure epoch poll would freeze them between membership changes;
        # do a full resolve at least this often for the load-aware
        # balancers (least / weighted)
        self.load_refresh_interval = load_refresh_interval
        self.default_timeout = default_timeout
        self.down_ttl = down_ttl
        self._view: Dict[str, Replica] = {}  #: guarded-by _view_lock
        self._view_epoch = -1  #: guarded-by _view_lock
        self._view_nonce: Optional[str] = None  #: guarded-by _view_lock
        self._next_epoch_check = 0.0  #: guarded-by _view_lock
        self._next_load_refresh = 0.0  #: guarded-by _view_lock
        self._view_lock = threading.Lock()
        self.refresh(force=True)

    def _make_gate(self) -> CreditGate:
        if not self.adaptive_credits:
            return CreditGate(self.credits_per_target)
        return AdaptiveCreditGate(
            self.credits_per_target, min_credits=self.credit_min,
            max_credits=self.credit_max,
            target_latency=self.credit_target_latency)

    # -- view management -----------------------------------------------------
    def refresh(self, force: bool = False) -> None:
        """Bring the cached replica view up to date.  Rate-limited epoch
        poll unless ``force``; full resolve when the epoch moved, the
        registry's nonce changed (restart), or piggybacked load is due."""
        now = time.monotonic()
        with self._view_lock:
            if not force and now < self._next_epoch_check:
                return
            self._next_epoch_check = now + self.refresh_interval
            load_due = now >= self._next_load_refresh
            have_epoch, have_nonce = self._view_epoch, self._view_nonce
        try:
            if not force and not load_due:
                # cheap poll first; resolve only when something moved
                epoch, nonce = self.registry.epoch_info()
                if epoch == have_epoch and nonce == have_nonce:
                    return
            # forced refreshes (retry/failover paths) must see the
            # authority — bypass the read cache but still singleflight
            view = self.registry.resolve(self.service,
                                         fresh=force or load_due)
        except MercuryError:
            return                        # registry briefly unreachable
        with self._view_lock:
            nonce = view.get("nonce")
            if nonce == self._view_nonce and view["epoch"] < self._view_epoch:
                # raced a newer refresh *of the same registry run*: keep
                # it.  A different nonce means the registry restarted and
                # reset its epoch — that view is fresher, never stale.
                return
            self._next_load_refresh = (time.monotonic()
                                       + self.load_refresh_interval)
            fresh: Dict[str, Replica] = {}
            for inst in view["instances"]:
                old = self._view.get(inst["iid"])
                if old is not None:
                    # keep gate/stats/demotions; update reported state
                    old.capacity = inst["capacity"]
                    old.load = inst["load"]
                    new_uris = _tier_sorted(inst["uris"])
                    if new_uris != old.uris:
                        # instance re-registered on new addresses (e.g.
                        # restarted on another port): demotions are stale
                        old.uris = new_uris
                        old.reresolve(self.engine)
                    fresh[inst["iid"]] = old
                else:
                    rep = Replica(inst["iid"], inst["uris"],
                                  inst["capacity"], inst["load"],
                                  self._make_gate())
                    rep.resolve(self.engine)
                    fresh[inst["iid"]] = rep
            self._view = fresh
            self._view_epoch = view["epoch"]
            self._view_nonce = nonce
        # unreachable-at-creation replicas get another chance each refresh
        for rep in fresh.values():
            if rep.route()[0] is None:
                rep.reresolve(self.engine)

    @property
    def epoch(self) -> int:
        with self._view_lock:
            return self._view_epoch

    def replicas(self) -> List[Replica]:
        with self._view_lock:
            return list(self._view.values())

    # -- call path -----------------------------------------------------------
    def call(self, rpc: str, arg: Any = None,
             timeout: Optional[float] = None,
             deadline: Optional[float] = None,
             policy: Optional[RetryPolicy] = None) -> Any:
        """Routed, deadline-bounded, retried (and optionally hedged) call.

        ``timeout`` is relative, ``deadline`` absolute (``monotonic``);
        deadline wins if both are given.
        """
        return self._call(rpc, arg, timeout, deadline, policy, None)[0]

    def call_routed(self, rpc: str, arg: Any = None,
                    timeout: Optional[float] = None,
                    deadline: Optional[float] = None,
                    policy: Optional[RetryPolicy] = None,
                    prefer: Optional[str] = None) -> tuple:
        """Like :meth:`call` but returns ``(value, iid)`` — the instance
        that actually served the request.  Use with :meth:`call_on` for
        replica-affine protocols (``gen.submit``'s rid only exists on the
        replica that admitted it).

        ``prefer`` is *soft* affinity: route to that instance first if it
        is live, but fall back to the normal balancer ranking when it is
        down, gone from the view, or has already failed this call — the
        session-affinity layer uses this so a dead KV-holding replica
        degrades to a fresh-prefill route instead of an error (contrast
        :meth:`call_on`, which is a hard pin)."""
        return self._call(rpc, arg, timeout, deadline, policy, None,
                          prefer=prefer)

    def call_on(self, iid: str, rpc: str, arg: Any = None,
                timeout: Optional[float] = None,
                deadline: Optional[float] = None,
                policy: Optional[RetryPolicy] = None) -> Any:
        """Pinned call: route only to instance ``iid`` (deadline/retry
        budget still applies; no hedging to other replicas).  If the
        instance left the view, the budget fails with
        ``BudgetExhausted`` whose cause is ``PoolError(NOENTRY)`` —
        retried rather than failed fast because a restarting instance
        re-registers under its old iid."""
        return self._call(rpc, arg, timeout, deadline, policy, iid)[0]

    def _call(self, rpc: str, arg: Any, timeout: Optional[float],
              deadline: Optional[float], policy: Optional[RetryPolicy],
              only_iid: Optional[str],
              prefer: Optional[str] = None) -> tuple:
        policy = policy or self.policy
        if deadline is None:
            deadline = time.monotonic() + (timeout if timeout is not None
                                           else self.default_timeout)
        # one logical call = one trace: root a new one here (head-sampled)
        # unless the caller is already inside a traced request, in which
        # case the pool call is a child span of it
        parent = _trace.current()
        root = (_trace.start_span(f"pool.{self.service}.{rpc}", parent)
                if parent is not None
                else _trace.start_trace(f"pool.{self.service}.{rpc}"))
        state = {"issued": 0, "failed_iids": set(), "winner": None,
                 "tctx": root.ctx}

        def attempt(idx: int, attempt_timeout: float) -> Any:
            if state["issued"] >= policy.attempts:
                # hedges consumed the remaining budget
                raise NonRetryable(BudgetExhausted(
                    f"{self.service}.{rpc}: attempt budget "
                    f"({policy.attempts}) consumed by hedged requests"))
            if idx > 0:
                self.refresh(force=True)   # pick up epoch bumps fast
            else:
                self.refresh()
            return self._attempt_once(rpc, arg, attempt_timeout, policy,
                                      state, deadline, only_iid,
                                      prefer=prefer)

        t0 = time.monotonic()
        _M_CALLS.inc()
        try:
            result = call_with_budget(policy, deadline, attempt)
        except BaseException as e:
            _M_CALL_ERRORS.inc()
            root.finish(_status_of(e), attempts=state["issued"])
            raise
        _M_CALL_MS.observe((time.monotonic() - t0) * 1e3)
        root.finish("OK", attempts=state["issued"], winner=state["winner"])
        return result, state["winner"]

    def _candidates(self, failed: set,
                    only_iid: Optional[str] = None,
                    prefer: Optional[str] = None) -> List[Replica]:
        reps = self.replicas()
        if only_iid is not None:
            reps = [r for r in reps if r.iid == only_iid]
        ranked = self.balancer.rank([r for r in reps if r.is_up])
        if not ranked and reps:
            # nobody is up: recover from (possibly stale) demotions and
            # mark-downs before declaring the service unreachable
            ranked = self.balancer.rank(
                [r for r in reps if r.reresolve(self.engine)])
        pref = [r for r in ranked if r.iid not in failed]
        # soft affinity last: a preferred iid that is down, gone, or in
        # ``failed`` never survives the filters above, so the fallback to
        # plain balancer order is automatic
        return prefer_instance(pref or ranked, prefer)

    def _attempt_once(self, rpc: str, arg: Any, attempt_timeout: float,
                      policy: RetryPolicy, state: dict, deadline: float,
                      only_iid: Optional[str] = None,
                      prefer: Optional[str] = None) -> Any:
        t_start = time.monotonic()
        # re-clamp to the caller's absolute deadline: the view refresh
        # that ran before this attempt burned real time after
        # attempt_timeout was computed
        attempt_deadline = min(t_start + attempt_timeout, deadline)
        candidates = self._candidates(state["failed_iids"], only_iid,
                                      prefer=prefer)
        if not candidates:
            raise PoolError(Ret.NOENTRY,
                            f"no live replicas for {self.service!r}"
                            + (f" (pinned to {only_iid})" if only_iid
                               else ""))

        t_adm = time.monotonic()
        primary = self._admit(candidates, attempt_deadline)
        admit_ms = (time.monotonic() - t_adm) * 1e3
        futs: List[CallFuture] = []
        owners: List[Replica] = []
        try:
            try:
                futs.append(self._issue(primary, rpc, arg, attempt_deadline,
                                        state, admit_ms=admit_ms))
            except MercuryError as e:
                # sync failure (e.g. un-encodable arg -> INVALID_ARG) gets
                # the same retryable/non-retryable classification as
                # errors delivered through futures
                self._note_failure(primary, e, state)
                self._raise_attempt_error(e)
            owners.append(primary)
            return self._await(futs, owners, rpc, arg, candidates, policy,
                               state, attempt_deadline, t_start)
        finally:
            for f in futs:
                if not f.done():
                    f.cancel_call()

    def _admit(self, candidates: List[Replica], attempt_deadline: float
               ) -> Replica:
        """Find a replica with a free credit; if everyone is saturated,
        wait (bounded) on the best-ranked gate — that wait *is* the
        backpressure the flow control is for."""
        for rep in candidates:
            if rep.gate.try_acquire():
                return rep
        best = candidates[0]
        wait = max(attempt_deadline - time.monotonic(), 0.0)
        if not best.gate.acquire(wait):
            raise PoolError(Ret.AGAIN,
                            f"{self.service}: all replicas saturated "
                            f"({best.gate.credits} credits each)")
        return best

    def _issue(self, rep: Replica, rpc: str, arg: Any,
               attempt_deadline: float, state: dict,
               admit_ms: float = 0.0, hedge: bool = False) -> CallFuture:
        """One wire RPC to one replica (credit already held); the credit
        is returned when the future settles, whatever settles it.

        Each issue is a child span of the call's trace, tagged with the
        replica it targeted, its credit-gate admission wait, and — when
        the future settles — its outcome (a hedge loser closes
        ``CANCELED``).  The span context is ambient around
        ``call_async`` so it rides the wire and the replica's server
        span becomes its child."""
        state["issued"] += 1
        _M_ATTEMPTS.inc()
        if hedge:
            _M_HEDGES.inc()
        addr, uri = rep.route()
        span = _trace.start_span(f"attempt.{rpc}", state.get("tctx"))
        if span.recorded:
            span.annotate(iid=rep.iid, uri=uri or "?",
                          n=state["issued"], hedge=hedge,
                          admit_ms=round(admit_ms, 3))
        try:
            with _trace.use(span.ctx):
                fut = self.engine.call_async(addr, rpc, arg,
                                             deadline=attempt_deadline)
        except BaseException as e:
            rep.gate.release()        # sync failure (e.g. MSGSIZE)
            span.finish(_status_of(e))
            raise
        # latency samples must start at ISSUE time: measuring from the
        # attempt start would fold our own credit-gate wait (and the
        # hedge delay) into the replica's latency, and the adaptive gate
        # would misread its own backpressure as server congestion — a
        # positive-feedback collapse of the limit
        fut.issued_at = time.monotonic()

        def _settled(f: CallFuture) -> None:
            rep.gate.release()
            span.finish(_status_of(f.exception()))

        fut.add_done_callback(_settled)
        return fut

    def _await(self, futs: List[CallFuture], owners: List[Replica],
               rpc: str, arg: Any, candidates: List[Replica],
               policy: RetryPolicy, state: dict, attempt_deadline: float,
               t_start: float) -> Any:
        """Wait for the attempt's future(s); launch a hedge once the
        hedge delay passes; first success wins and the loser is canceled."""
        hedged = False
        pending = list(futs)
        while True:
            now = time.monotonic()
            remaining = attempt_deadline - now
            if remaining <= 0 and pending:
                # this wall-clock check usually beats the transport's own
                # deadline timer: the hung replicas must still take the
                # TIMEOUT congestion penalty and attempt-level exclusion
                err = RemoteError(Ret.TIMEOUT, f"{rpc}: attempt timed out")
                for f in pending:
                    self._note_failure(owners[futs.index(f)], err, state)
                raise err
            wait_for = remaining
            if (not hedged and policy.hedge_after is not None
                    and state["issued"] < policy.attempts):
                wait_for = min(wait_for,
                               max(t_start + policy.hedge_after - now, 0.0))
            done, not_done = cf.wait(pending, timeout=max(wait_for, 0.0),
                                     return_when=cf.FIRST_COMPLETED)
            for f in done:
                pending.remove(f)
                rep = owners[futs.index(f)]
                err = f.exception()
                if err is None:
                    rep.record(time.monotonic() - f.issued_at, ok=True)
                    state["winner"] = rep.iid
                    return f.result()
                self._note_failure(rep, err, state)
            if not pending and done:
                # every issued future failed: surface the last error to
                # the budget loop (retryable or not decided there)
                self._raise_attempt_error(err)
            if (not hedged and policy.hedge_after is not None
                    and time.monotonic() - t_start >= policy.hedge_after
                    and state["issued"] < policy.attempts):
                hedged = True
                hedge_rep = self._hedge_candidate(candidates, owners)
                if hedge_rep is not None:
                    futs.append(self._issue(hedge_rep, rpc, arg,
                                            attempt_deadline, state,
                                            hedge=True))
                    owners.append(hedge_rep)
                    pending.append(futs[-1])
            if not pending:
                raise RemoteError(Ret.TIMEOUT, f"{rpc}: attempt timed out")

    def _hedge_candidate(self, candidates: List[Replica],
                         owners: List[Replica]) -> Optional[Replica]:
        for rep in candidates:
            if rep not in owners and rep.gate.try_acquire():
                return rep
        return None

    def _note_failure(self, rep: Replica, err: BaseException,
                      state: dict) -> None:
        rep.record(None, ok=False)
        state["failed_iids"].add(rep.iid)
        ret = getattr(err, "ret", None)
        if ret in _CONGESTION:
            rep.penalize()                # adaptive gate: shrink the limit
        if ret in _TIER_FAULTS:
            # the resolved tier is broken (e.g. stale sm segment after a
            # replica restart): demote it; no fallback tier -> mark down
            if not rep.demote(self.engine):
                rep.mark_down(self.down_ttl)
        elif ret is not None and ret not in _RETRYABLE:
            pass                          # application error: replica fine

    @staticmethod
    def _raise_attempt_error(err: BaseException) -> None:
        ret = getattr(err, "ret", None)
        if ret is not None and ret not in _RETRYABLE:
            raise NonRetryable(err)
        raise err

    # -- conveniences --------------------------------------------------------
    def call_each(self, rpc: str, arg: Any = None,
                  timeout: Optional[float] = None) -> Dict[str, Any]:
        """Call every live replica once (admin/broadcast helper); returns
        {iid: result-or-exception}."""
        out: Dict[str, Any] = {}
        for rep in self.replicas():
            if not rep.is_up:
                continue
            try:
                out[rep.iid] = self.engine.call(
                    rep.route()[0], rpc, arg,
                    timeout=timeout or self.default_timeout)
            except Exception as e:        # noqa: BLE001 — broadcast survey
                out[rep.iid] = e
        return out

    def stats(self) -> dict:
        return {"service": self.service, "epoch": self.epoch,
                "balancer": self.balancer.name,
                "replicas": [r.stat() for r in self.replicas()]}

    def close(self) -> None:
        """The pool owns no threads; kept for symmetry with servers."""
