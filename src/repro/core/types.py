"""Core types shared across the Mercury-style RPC stack.

Mirrors the public surface of Mercury (hg_core): return codes, operation
types, headers.  Headers are fixed-size packed structs so that decoding an
incoming unexpected message is O(1) and allocation-free.
"""
from __future__ import annotations

import enum
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

PROTOCOL_VERSION = 5
MIN_PROTOCOL_VERSION = 4   # oldest peer version we still decode
HEADER_MAGIC = 0x4D4A5250  # "MJRP"
ZERO_TRACE_ID = b"\x00" * 16


class Ret(enum.IntEnum):
    """Return codes (subset of hg_return_t)."""

    SUCCESS = 0
    TIMEOUT = 1
    CANCELED = 2
    NOENTRY = 3          # RPC id not registered on target
    PROTOCOL_ERROR = 4
    CHECKSUM_ERROR = 5
    NOMEM = 6
    INVALID_ARG = 7
    FAULT = 8            # remote handler raised
    DISCONNECT = 9
    AGAIN = 10
    PERMISSION = 11
    MSGSIZE = 12         # message exceeds the transport's eager limit
    OVERLOAD = 13        # target shed the request (admission control):
                         # it cannot finish within the caller's deadline


class OpType(enum.IntEnum):
    """Completion-entry operation types (hg_cb_type)."""

    FORWARD = 0      # origin: response arrived (or send-only completed)
    RESPOND = 1      # target: response send completed
    BULK = 2         # bulk transfer completed
    LOOKUP = 3
    RPC_HANDLER = 4  # target: incoming RPC ready to execute
    SEND = 5
    RECV = 6


class MercuryError(Exception):
    def __init__(self, ret: Ret, msg: str = ""):
        self.ret = Ret(ret)
        super().__init__(f"{self.ret.name}: {msg}" if msg else self.ret.name)


class ChecksumError(MercuryError):
    def __init__(self, msg: str = ""):
        super().__init__(Ret.CHECKSUM_ERROR, msg)


# --------------------------------------------------------------------------
# Wire headers
# --------------------------------------------------------------------------
# Request v5 (64 B): magic u32 | version u8 | flags u8 | pad u16
#          | rpc_id u64 | cookie u64 | payload_len u32 | payload_crc u32
#          | budget_ms u32 (remaining deadline budget; 0 = unbounded)
#          | trace_id 16B | span_id u64 | trace_flags u8 | pad 3B
_REQ = struct.Struct("<IBBHQQIII16sQB3x")
# Request v4 (36 B): same prefix, no trace fields.  Still decoded for
# back-compat; a v4 peer's requests must keep working mid-upgrade.
_REQ_V4 = struct.Struct("<IBBHQQIII")
# Response: magic u32 | version u8 | ret u8 | pad u16 | cookie u64
#           | payload_len u32 | payload_crc u32
# Byte-identical across v4/v5 (responses carry no trace context: spans
# are collected server-side via dbg.trace) — only the version byte
# differs, and a target echoes the requester's version so a v4 peer's
# responses neither grow nor get rejected.
_RSP = struct.Struct("<IBBHQII")

REQUEST_HEADER_SIZE = _REQ.size
REQUEST_HEADER_SIZE_V4 = _REQ_V4.size
RESPONSE_HEADER_SIZE = _RSP.size


class Flags(enum.IntFlag):
    NONE = 0
    NO_RESPONSE = 1      # fire-and-forget RPC
    CHECKSUM = 2         # payload CRC is present/verified
    RENDEZVOUS = 4       # body is a bulk descriptor; target pulls the payload


@dataclass(frozen=True)
class RequestHeader:
    rpc_id: int
    cookie: int
    flags: Flags = Flags.NONE
    payload_len: int = 0
    payload_crc: int = 0
    # remaining deadline budget at send time, milliseconds; 0 = caller set
    # no deadline.  Targets use it for admission control (shed with
    # Ret.OVERLOAD when the estimated queue wait already exceeds it).
    budget_ms: int = 0
    # trace context (v5, DESIGN.md §10): zeroed = untraced request.  A v4
    # peer's header decodes with these left at their zero defaults.
    trace_id: bytes = ZERO_TRACE_ID
    span_id: int = 0
    trace_flags: int = 0
    # decoded wire version (v4 headers are shorter; targets echo this in
    # the response so old peers keep decoding us)
    version: int = PROTOCOL_VERSION

    @property
    def wire_size(self) -> int:
        """Actual on-wire size of this header (version-dependent) — the
        dispatcher slices the body at this offset, never at the constant."""
        return REQUEST_HEADER_SIZE_V4 if self.version == 4 \
            else REQUEST_HEADER_SIZE

    def pack(self) -> bytes:
        if self.version == 4:
            # legacy layout: trace fields dropped (tests and mixed-version
            # rings craft these; this process always sends v5)
            return _REQ_V4.pack(
                HEADER_MAGIC, 4, int(self.flags), 0,
                self.rpc_id, self.cookie, self.payload_len,
                self.payload_crc, self.budget_ms,
            )
        return _REQ.pack(
            HEADER_MAGIC, PROTOCOL_VERSION, int(self.flags), 0,
            self.rpc_id, self.cookie, self.payload_len, self.payload_crc,
            self.budget_ms, self.trace_id, self.span_id, self.trace_flags,
        )

    @staticmethod
    def unpack(buf: bytes | memoryview) -> "RequestHeader":
        magic, ver = struct.unpack_from("<IB", buf)
        if magic != HEADER_MAGIC:
            raise MercuryError(Ret.PROTOCOL_ERROR, f"bad magic {magic:#x}")
        if ver == PROTOCOL_VERSION:
            (_magic, _ver, flags, _pad, rpc_id, cookie, plen, crc, budget_ms,
             trace_id, span_id, trace_flags) = _REQ.unpack_from(buf)
            return RequestHeader(rpc_id, cookie, Flags(flags), plen, crc,
                                 budget_ms, bytes(trace_id), span_id,
                                 trace_flags, PROTOCOL_VERSION)
        if ver == 4:
            (_magic, _ver, flags, _pad, rpc_id, cookie, plen, crc,
             budget_ms) = _REQ_V4.unpack_from(buf)
            return RequestHeader(rpc_id, cookie, Flags(flags), plen, crc,
                                 budget_ms, version=4)
        raise MercuryError(
            Ret.PROTOCOL_ERROR,
            f"version {ver} unsupported (accept "
            f"{MIN_PROTOCOL_VERSION}..{PROTOCOL_VERSION})")


@dataclass(frozen=True)
class ResponseHeader:
    cookie: int
    ret: Ret = Ret.SUCCESS
    payload_len: int = 0
    payload_crc: int = 0
    # targets echo the requester's version; the layout is identical either
    # way, so v4 peers see responses of the exact size they expect
    version: int = PROTOCOL_VERSION

    def pack(self) -> bytes:
        return _RSP.pack(
            HEADER_MAGIC, self.version, int(self.ret), 0,
            self.cookie, self.payload_len, self.payload_crc,
        )

    @staticmethod
    def unpack(buf: bytes | memoryview) -> "ResponseHeader":
        magic, ver, ret, _pad, cookie, plen, crc = _RSP.unpack_from(buf)
        if magic != HEADER_MAGIC:
            raise MercuryError(Ret.PROTOCOL_ERROR, f"bad magic {magic:#x}")
        if not (MIN_PROTOCOL_VERSION <= ver <= PROTOCOL_VERSION):
            raise MercuryError(
                Ret.PROTOCOL_ERROR,
                f"version {ver} unsupported (accept "
                f"{MIN_PROTOCOL_VERSION}..{PROTOCOL_VERSION})")
        return ResponseHeader(cookie, Ret(ret), plen, crc, ver)


def payload_crc32(data: bytes | memoryview) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


# --------------------------------------------------------------------------
# Completion entries
# --------------------------------------------------------------------------
@dataclass
class CallbackInfo:
    """Passed to user callbacks when a completion entry is triggered
    (hg_cb_info)."""

    op_type: OpType
    ret: Ret
    # op-specific payloads:
    handle: Any = None        # Handle for FORWARD / RPC_HANDLER / RESPOND
    bulk_op: Any = None       # BulkOp for BULK
    arg: Any = None           # user arg given at post time


Callback = Callable[[CallbackInfo], None]


class _Counter:
    """Monotonic thread-safe u64 counter (cookies, op ids, mem keys)."""

    def __init__(self, start: int = 1):
        self._v = start  #: guarded-by _lock
        self._lock = threading.Lock()

    def next(self) -> int:
        with self._lock:
            v = self._v
            self._v = (self._v + 1) & 0xFFFFFFFFFFFFFFFF
            return v


def stable_rpc_id(name: str) -> int:
    """Stable 64-bit id for an RPC name (Mercury hashes the func name).

    CRC64-ish via two CRC32 passes; stable across processes/runs which is
    what matters for origin/target agreement.
    """
    b = name.encode()
    hi = zlib.crc32(b)
    lo = zlib.crc32(b[::-1] + b"\x9e")
    v = ((hi << 32) | lo) & 0xFFFFFFFFFFFFFFFF
    return v or 1
