"""Bulk data layer (paper contribution C3).

The key Mercury idea for large arguments: the RPC message carries only a
*bulk descriptor* (registered-memory coordinates); the payload itself is
moved by one-sided put/get over the native transport, pipelined in chunks,
initiated by whichever side the service logic prefers (usually the target
pulls). This avoids serialization copies entirely and removes the size
limit of eager RPC messages.

``BulkHandle``   — local registered memory (possibly multi-segment).
``BulkDescriptor`` — the serializable remote view of a handle.
``bulk_transfer`` — pipelined one-sided GET/PUT between a local handle and
a remote descriptor, with segment-crossing offset resolution on both sides.
"""
from __future__ import annotations

import struct
import threading
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .na.base import NAAddress, NACap, NAMemHandle, NAPlugin
from .progress import Context
from .types import CallbackInfo, MercuryError, OpType, Ret

DEFAULT_CHUNK = 4 * 1024 * 1024
DEFAULT_INFLIGHT = 4


class BulkOpType(IntEnum):
    GET = 0   # remote -> local
    PUT = 1   # local -> remote


@dataclass
class BulkSegment:
    key: int
    size: int


@dataclass
class BulkDescriptor:
    """Serializable description of remote registered memory."""

    owner_uri: str
    segments: List[BulkSegment]
    read_allowed: bool = True
    write_allowed: bool = True

    @property
    def size(self) -> int:
        return sum(s.size for s in self.segments)

    # -- wire format ---------------------------------------------------------
    def to_bytes(self) -> bytes:
        uri = self.owner_uri.encode()
        out = struct.pack("<HBB", len(uri), int(self.read_allowed),
                          int(self.write_allowed)) + uri
        out += struct.pack("<I", len(self.segments))
        for s in self.segments:
            out += struct.pack("<QQ", s.key, s.size)
        return out

    @staticmethod
    def from_bytes(data: bytes | memoryview) -> "BulkDescriptor":
        data = memoryview(data)
        ulen, r, w = struct.unpack_from("<HBB", data)
        off = 4
        uri = bytes(data[off:off + ulen]).decode()
        off += ulen
        (nseg,) = struct.unpack_from("<I", data, off)
        off += 4
        segs = []
        for _ in range(nseg):
            key, size = struct.unpack_from("<QQ", data, off)
            off += 16
            segs.append(BulkSegment(key, size))
        return BulkDescriptor(uri, segs, bool(r), bool(w))


class BulkHandle:
    """Locally registered (possibly multi-segment) memory region."""

    def __init__(self, na: NAPlugin, buffers: Sequence[np.ndarray | memoryview | bytearray],
                 read: bool = True, write: bool = True):
        self.na = na
        self.buffers = list(buffers)
        self.mem: List[NAMemHandle] = [
            na.mem_register(b, read=read, write=write) for b in self.buffers
        ]
        self.read_allowed = read
        self.write_allowed = write

    @property
    def size(self) -> int:
        return sum(m.size for m in self.mem)

    def descriptor(self) -> BulkDescriptor:
        return BulkDescriptor(
            owner_uri=self.na.addr_self().uri,
            segments=[BulkSegment(m.key, m.size) for m in self.mem],
            read_allowed=self.read_allowed,
            write_allowed=self.write_allowed,
        )

    def free(self) -> None:
        for m in self.mem:
            self.na.mem_deregister(m)
        self.mem = []

    # -- segment resolution ----------------------------------------------------
    def _resolve(self, offset: int, size: int) -> List[Tuple[NAMemHandle, int, int]]:
        return _resolve_segments([(m, m.size) for m in self.mem], offset, size)


def _resolve_segments(segs: List[Tuple[object, int]], offset: int,
                      size: int) -> List[Tuple[object, int, int]]:
    """Map a flat (offset, size) range onto (segment, seg_off, length) pieces."""
    out = []
    pos = 0
    need = size
    for seg, seg_size in segs:
        if need == 0:
            break
        seg_start = pos
        seg_end = pos + seg_size
        pos = seg_end
        if offset >= seg_end:
            continue
        start_in_seg = max(0, offset - seg_start)
        avail = seg_size - start_in_seg
        take = min(avail, need)
        if take > 0:
            out.append((seg, start_in_seg, take))
            offset += take
            need -= take
    if need:
        raise MercuryError(Ret.INVALID_ARG,
                           f"bulk range [{offset}, +{need}) exceeds handle")
    return out


class BulkOp:
    """Tracks a pipelined multi-chunk transfer."""

    def __init__(self, total: int):
        self.total = total
        self.transferred = 0  #: guarded-by _lock
        self.ret = Ret.SUCCESS  #: guarded-by _lock
        self.canceled = False          # one-way latch; racy read is fine
        self._lock = threading.Lock()


def bulk_transfer(context: Context, op: BulkOpType, remote_addr: NAAddress,
                  remote: BulkDescriptor, remote_offset: int,
                  local: BulkHandle, local_offset: int, size: int,
                  cb: Optional[Callable[[CallbackInfo], None]] = None,
                  arg=None, chunk_size: int = DEFAULT_CHUNK,
                  max_inflight: int = DEFAULT_INFLIGHT) -> BulkOp:
    """One-sided pipelined transfer between ``local`` and ``remote``.

    GET pulls remote→local, PUT pushes local→remote. Chunks are issued up
    to ``max_inflight`` deep; completion posts a BULK entry on ``context``.
    """
    na = local.na
    if op == BulkOpType.GET and not remote.read_allowed:
        raise MercuryError(Ret.PERMISSION, "remote descriptor is not readable")
    if op == BulkOpType.PUT and not remote.write_allowed:
        raise MercuryError(Ret.PERMISSION, "remote descriptor is not writable")
    if size == 0:
        bop = BulkOp(0)
        context.completion_add(cb, CallbackInfo(OpType.BULK, Ret.SUCCESS,
                                                bulk_op=bop, arg=arg))
        return bop

    # Zero-copy fast path: when the plugin's put/get against this peer is a
    # native one-sided copy, chunking/pipelining only adds bookkeeping —
    # issue each contiguous segment pair as a single transfer.
    if na.caps_for(remote_addr) & NACap.NATIVE_RMA:
        chunk_size = max(chunk_size, size)

    local_pieces = local._resolve(local_offset, size)
    remote_segs = [(s, s.size) for s in remote.segments]
    remote_pieces = _resolve_segments(remote_segs, remote_offset, size)

    # Align local and remote piece lists into common (len-limited) chunks.
    chunks: List[Tuple[NAMemHandle, int, BulkSegment, int, int]] = []
    li = ri = 0
    lmem, loff, llen = local_pieces[0]
    rseg, roff, rlen = remote_pieces[0]
    while True:
        take = min(llen, rlen, chunk_size)
        chunks.append((lmem, loff, rseg, roff, take))
        loff += take; llen -= take
        roff += take; rlen -= take
        if llen == 0:
            li += 1
            if li < len(local_pieces):
                lmem, loff, llen = local_pieces[li]
        if rlen == 0:
            ri += 1
            if ri < len(remote_pieces):
                rseg, roff, rlen = remote_pieces[ri]
        if li >= len(local_pieces) or ri >= len(remote_pieces):
            break

    bop = BulkOp(size)
    state = {"next": 0, "outstanding": 0, "failed": None, "done": False}
    lock = threading.Lock()

    def finish(ret: Ret):
        with lock:
            if state["done"]:
                return
            state["done"] = True
        with bop._lock:
            bop.ret = ret
        context.completion_add(cb, CallbackInfo(OpType.BULK, ret,
                                                bulk_op=bop, arg=arg))

    def pump():
        while True:
            with lock:
                if state["failed"] is not None or bop.canceled:
                    if state["outstanding"] == 0:
                        pass
                    break
                if state["next"] >= len(chunks):
                    break
                if state["outstanding"] >= max_inflight:
                    break
                idx = state["next"]
                state["next"] += 1
                state["outstanding"] += 1
            lmem_i, loff_i, rseg_i, roff_i, n_i = chunks[idx]
            rmh = NAMemHandle(key=rseg_i.key, size=rseg_i.size,
                              owner_uri=remote.owner_uri,
                              read_allowed=remote.read_allowed,
                              write_allowed=remote.write_allowed)

            def on_chunk(ret: Ret, _n=n_i):
                with lock:
                    state["outstanding"] -= 1
                    if ret != Ret.SUCCESS:
                        state["failed"] = ret
                    failed = state["failed"]
                    outstanding = state["outstanding"]
                moved = -1
                if ret == Ret.SUCCESS:
                    with bop._lock:
                        bop.transferred += _n
                        moved = bop.transferred
                if moved == size:
                    finish(Ret.SUCCESS)
                elif failed is not None and outstanding == 0:
                    finish(failed)
                else:
                    pump()

            if op == BulkOpType.GET:
                na.get(lmem_i, loff_i, remote_addr, rmh, roff_i, n_i, on_chunk)
            else:
                na.put(lmem_i, loff_i, remote_addr, rmh, roff_i, n_i, on_chunk)

    pump()
    return bop


# -- convenience: expose ndarray pytrees -------------------------------------
def expose_arrays(na: NAPlugin, arrays: Sequence[np.ndarray],
                  read: bool = True, write: bool = True) -> BulkHandle:
    """Register a list of C-contiguous ndarrays as one multi-segment handle."""
    bufs = []
    for a in arrays:
        if not isinstance(a, np.ndarray):
            raise MercuryError(Ret.INVALID_ARG, "expose_arrays expects ndarrays")
        bufs.append(np.ascontiguousarray(a))
    return BulkHandle(na, bufs, read=read, write=write)
