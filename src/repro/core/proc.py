"""Serialization ("proc") framework — Mercury's hg_proc equivalent.

A *proc* is a single function that either encodes or decodes a value
depending on the direction of the :class:`ProcBuf` it is given — the same
one-function-both-directions idiom Mercury uses so that argument encoders
cannot drift between the two directions.

    def proc_point(p: ProcBuf, v):
        x = proc_float64(p, v.x if p.encoding else None)
        y = proc_float64(p, v.y if p.encoding else None)
        return v if p.encoding else Point(x, y)

In practice users rarely hand-write procs: :func:`derive` builds one from
a dataclass's type hints, and combinators (:func:`list_of`,
:func:`optional`, :func:`dict_of`, ...) compose them.

Large binary payloads (ndarrays) have two paths, mirroring the paper's
eager/bulk split:
  * :func:`proc_ndarray` — inline (eager), for small arrays;
  * bulk descriptors (see ``core/bulk.py``) serialized with
    :func:`proc_bytes` — the RPC then carries only the descriptor and the
    target pulls the payload one-sidedly.
"""
from __future__ import annotations

import struct
import typing
from dataclasses import MISSING, fields, is_dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from .types import MercuryError, Ret

Proc = Callable[["ProcBuf", Any], Any]

# Copy-discipline thresholds (DESIGN.md §9).  Below ZEROCOPY_MIN a decoded
# bytes value is materialized as ``bytes`` (tiny, hashable, universally
# accepted); at or above it the decoder returns a read-only memoryview into
# the message buffer — zero copies, valid for the message's lifetime (every
# transport hands the RPC layer an owning buffer).  ENCODE_VIEW_MIN is the
# point at which ``encode`` stops flattening its bytearray into a fresh
# ``bytes`` (the second full-buffer copy) and returns a memoryview instead.
ZEROCOPY_MIN = 4096
ENCODE_VIEW_MIN = 64 * 1024


class ProcBuf:
    """Encode/decode cursor. ``encoding=True`` appends; else it consumes."""

    __slots__ = ("encoding", "_buf", "_view", "_pos")

    def __init__(self, encoding: bool, data: bytes | memoryview | None = None):
        self.encoding = encoding
        if encoding:
            self._buf = bytearray()
            self._view = None
        else:
            if data is None:
                raise MercuryError(Ret.INVALID_ARG, "decode ProcBuf needs data")
            self._buf = None
            self._view = memoryview(data)
        self._pos = 0

    # -- encode side -------------------------------------------------------
    def write(self, data: bytes | memoryview) -> None:
        self._buf += data

    def getvalue(self) -> bytes:
        return bytes(self._buf)

    def getbuffer(self) -> memoryview:
        """Zero-copy view of the encoded buffer.  The ProcBuf must not be
        written to while the view is exported (bytearray resize would
        raise BufferError) — callers take the view only once encoding is
        finished."""
        return memoryview(self._buf)

    # -- decode side -------------------------------------------------------
    def read(self, n: int) -> memoryview:
        if self._pos + n > len(self._view):
            raise MercuryError(
                Ret.PROTOCOL_ERROR,
                f"proc underflow: want {n} at {self._pos}, have {len(self._view)}",
            )
        out = self._view[self._pos : self._pos + n]
        self._pos += n
        return out

    def remaining(self) -> int:
        return 0 if self.encoding else len(self._view) - self._pos

    def done(self) -> bool:
        return self.encoding or self._pos == len(self._view)


def _scalar(fmt: str) -> Proc:
    st = struct.Struct("<" + fmt)

    def proc(p: ProcBuf, v=None):
        if p.encoding:
            p.write(st.pack(v))
            return v
        return st.unpack_from(p.read(st.size))[0]

    return proc


proc_uint8 = _scalar("B")
proc_uint16 = _scalar("H")
proc_uint32 = _scalar("I")
proc_uint64 = _scalar("Q")
proc_int8 = _scalar("b")
proc_int16 = _scalar("h")
proc_int32 = _scalar("i")
proc_int64 = _scalar("q")
proc_float32 = _scalar("f")
proc_float64 = _scalar("d")


def proc_bool(p: ProcBuf, v=None):
    if p.encoding:
        p.write(b"\x01" if v else b"\x00")
        return v
    return p.read(1)[0] != 0


def proc_varint(p: ProcBuf, v=None):
    """LEB128 unsigned varint — compact lengths on the wire."""
    if p.encoding:
        n = int(v)
        if n < 0:
            raise MercuryError(Ret.INVALID_ARG, "varint must be >= 0")
        out = bytearray()
        while True:
            b = n & 0x7F
            n >>= 7
            out.append(b | (0x80 if n else 0))
            if not n:
                break
        p.write(out)
        return v
    shift, n = 0, 0
    while True:
        b = p.read(1)[0]
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n
        shift += 7
        if shift > 63:
            raise MercuryError(Ret.PROTOCOL_ERROR, "varint overflow")


def proc_bytes(p: ProcBuf, v=None):
    if p.encoding:
        proc_varint(p, len(v))
        p.write(v)
        return v
    n = proc_varint(p)
    if n < ZEROCOPY_MIN:
        return bytes(p.read(n))
    # large payload: hand back a read-only view into the message buffer
    # (no copy).  Read-only keeps it hashable and content-comparable with
    # bytes; callers needing an owning copy do bytes(view) explicitly.
    return p.read(n).toreadonly()


def proc_str(p: ProcBuf, v=None):
    if p.encoding:
        proc_bytes(p, v.encode("utf-8"))
        return v
    # decode straight from the buffer view: one copy (the str), not two
    n = proc_varint(p)
    return str(p.read(n), "utf-8")


def proc_none(p: ProcBuf, v=None):
    return None


# --------------------------------------------------------------------------
# ndarray (inline / eager path)
# --------------------------------------------------------------------------
def proc_ndarray(p: ProcBuf, v: Optional[np.ndarray] = None):
    """Inline ndarray: dtype str | ndim | shape... | raw bytes (C order).

    Decoding is zero-copy when the source buffer permits (returns an array
    viewing the message buffer; callers own the message lifetime).
    """
    if p.encoding:
        a = np.ascontiguousarray(v)
        proc_str(p, a.dtype.str)
        proc_varint(p, a.ndim)
        for d in a.shape:
            proc_varint(p, d)
        p.write(memoryview(a).cast("B"))
        return v
    dt = np.dtype(proc_str(p))
    ndim = proc_varint(p)
    shape = tuple(proc_varint(p) for _ in range(ndim))
    nbytes = dt.itemsize * int(np.prod(shape)) if shape else dt.itemsize * 1
    count = int(np.prod(shape)) if shape else 1
    raw = p.read(count * dt.itemsize)
    arr = np.frombuffer(raw, dtype=dt, count=count).reshape(shape)
    return arr


# --------------------------------------------------------------------------
# Combinators
# --------------------------------------------------------------------------
def list_of(item: Proc) -> Proc:
    def proc(p: ProcBuf, v=None):
        if p.encoding:
            proc_varint(p, len(v))
            for it in v:
                item(p, it)
            return v
        n = proc_varint(p)
        return [item(p) for _ in range(n)]

    return proc


def tuple_of(*items: Proc) -> Proc:
    def proc(p: ProcBuf, v=None):
        if p.encoding:
            if len(v) != len(items):
                raise MercuryError(Ret.INVALID_ARG, "tuple arity mismatch")
            for it, x in zip(items, v):
                it(p, x)
            return v
        return tuple(it(p) for it in items)

    return proc


def dict_of(key: Proc, val: Proc) -> Proc:
    def proc(p: ProcBuf, v=None):
        if p.encoding:
            proc_varint(p, len(v))
            for k in v:
                key(p, k)
                val(p, v[k])
            return v
        n = proc_varint(p)
        return {key(p): val(p) for _ in range(n)}

    return proc


def optional(item: Proc) -> Proc:
    def proc(p: ProcBuf, v=None):
        if p.encoding:
            proc_bool(p, v is not None)
            if v is not None:
                item(p, v)
            return v
        return item(p) if proc_bool(p) else None

    return proc


# --------------------------------------------------------------------------
# Dataclass derivation
# --------------------------------------------------------------------------
_ATOM_PROCS: Dict[Any, Proc] = {
    int: proc_int64,
    float: proc_float64,
    bool: proc_bool,
    str: proc_str,
    bytes: proc_bytes,
    np.ndarray: proc_ndarray,
    type(None): proc_none,
}

_derived_cache: Dict[type, Proc] = {}


def register_atom(tp: type, proc: Proc) -> None:
    """Let upper layers plug custom wire types (paper C6: serialization may
    be provided by upper layers)."""
    _ATOM_PROCS[tp] = proc


def proc_for(tp: Any) -> Proc:
    """Resolve a proc for a type annotation."""
    if tp in _ATOM_PROCS:
        return _ATOM_PROCS[tp]
    origin = typing.get_origin(tp)
    args = typing.get_args(tp)
    if origin in (list, List):
        return list_of(proc_for(args[0]))
    if origin in (dict, Dict):
        return dict_of(proc_for(args[0]), proc_for(args[1]))
    if origin in (tuple, Tuple):
        if len(args) == 2 and args[1] is Ellipsis:
            inner = list_of(proc_for(args[0]))

            def proc_vtuple(p, v=None, _inner=inner):
                if p.encoding:
                    _inner(p, list(v))
                    return v
                return tuple(_inner(p))

            return proc_vtuple
        return tuple_of(*(proc_for(a) for a in args))
    if origin is typing.Union:
        non_none = [a for a in args if a is not type(None)]
        if len(non_none) == 1 and len(args) == 2:
            return optional(proc_for(non_none[0]))
        raise MercuryError(Ret.INVALID_ARG, f"unsupported Union {tp}")
    if is_dataclass(tp):
        return derive(tp)
    raise MercuryError(Ret.INVALID_ARG, f"no proc for type {tp!r}")


def derive(cls: type) -> Proc:
    """Derive a proc for a dataclass from its type hints (cached)."""
    if cls in _derived_cache:
        return _derived_cache[cls]
    if not is_dataclass(cls):
        raise MercuryError(Ret.INVALID_ARG, f"{cls} is not a dataclass")

    # placeholder to allow recursive types
    def _placeholder(p, v=None):
        return _derived_cache[cls](p, v)

    _derived_cache[cls] = _placeholder
    hints = typing.get_type_hints(cls)
    field_procs = [(f.name, proc_for(hints[f.name])) for f in fields(cls)]

    def proc(p: ProcBuf, v=None):
        if p.encoding:
            for name, fp in field_procs:
                fp(p, getattr(v, name))
            return v
        return cls(**{name: fp(p) for name, fp in field_procs})

    _derived_cache[cls] = proc
    return proc


# --------------------------------------------------------------------------
# Convenience entry points used by rpc.py
# --------------------------------------------------------------------------
def encode(proc: Proc, value: Any) -> bytes | memoryview:
    p = ProcBuf(encoding=True)
    proc(p, value)
    # fast path: past ENCODE_VIEW_MIN the flatten-to-bytes costs a second
    # full-buffer copy; return a view of the (now write-complete) buffer
    # instead.  Small messages stay bytes — cheap, and senders concatenate
    # them freely.
    if len(p._buf) >= ENCODE_VIEW_MIN:
        return p.getbuffer()
    return p.getvalue()


def decode(proc: Proc, data: bytes | memoryview) -> Any:
    p = ProcBuf(encoding=False, data=data)
    v = proc(p)
    return v


# A permissive default proc for ad-hoc python values (tagged union).
def proc_any(p: ProcBuf, v=None):
    """Self-describing proc for JSON-ish python values + ndarray/bytes.

    Used as the default in/out proc so services can pass plain dicts
    without declaring dataclasses; hot paths should declare real procs.
    """
    TAGS = {type(None): 0, bool: 1, int: 2, float: 3, str: 4, bytes: 5,
            list: 6, tuple: 7, dict: 8, np.ndarray: 9}
    if p.encoding:
        t = type(v)
        if isinstance(v, np.ndarray):
            t = np.ndarray
        elif isinstance(v, bool):
            t = bool  # before int: bool is an int subclass
        elif isinstance(v, (np.integer,)):
            v, t = int(v), int
        elif isinstance(v, (np.floating,)):
            v, t = float(v), float
        elif isinstance(v, (memoryview, bytearray)):
            t = bytes  # zero-copy decoded views re-encode as bytes
        if t not in TAGS:
            raise MercuryError(Ret.INVALID_ARG, f"proc_any: {t}")
        proc_uint8(p, TAGS[t])
        if t is type(None):
            pass
        elif t is bool:
            proc_bool(p, v)
        elif t is int:
            proc_int64(p, v)
        elif t is float:
            proc_float64(p, v)
        elif t is str:
            proc_str(p, v)
        elif t is bytes:
            proc_bytes(p, v)
        elif t in (list, tuple):
            proc_varint(p, len(v))
            for it in v:
                proc_any(p, it)
        elif t is dict:
            proc_varint(p, len(v))
            for k, val in v.items():
                proc_any(p, k)
                proc_any(p, val)
        elif t is np.ndarray:
            proc_ndarray(p, v)
        return v
    tag = proc_uint8(p)
    if tag == 0:
        return None
    if tag == 1:
        return proc_bool(p)
    if tag == 2:
        return proc_int64(p)
    if tag == 3:
        return proc_float64(p)
    if tag == 4:
        return proc_str(p)
    if tag == 5:
        return proc_bytes(p)
    if tag in (6, 7):
        n = proc_varint(p)
        xs = [proc_any(p) for _ in range(n)]
        return xs if tag == 6 else tuple(xs)
    if tag == 8:
        n = proc_varint(p)
        return {proc_any(p): proc_any(p) for _ in range(n)}
    if tag == 9:
        return proc_ndarray(p)
    raise MercuryError(Ret.PROTOCOL_ERROR, f"proc_any: bad tag {tag}")
