"""Progress / completion-queue model (paper contribution C5).

Mercury's execution model: when an operation completes, the user callback
is *placed onto a completion queue* — it is executed only when the user
calls ``trigger()``. ``progress()`` drives the underlying NA transport.
The split is what enables high concurrency: a dedicated thread can spin
``progress`` while a pool of worker threads drains ``trigger``, or a
single-threaded user can interleave both — both patterns are implemented
in ``executor.py`` on top of this file, unchanged, which is the paper's
point about shim layers.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Deque, Optional, Tuple

from .na.base import NAPlugin
from .types import Callback, CallbackInfo, Ret


class Context:
    """An execution context: one completion queue bound to one NA plugin."""

    def __init__(self, na: NAPlugin):
        self.na = na
        self._cq: Deque[Tuple[Callback, CallbackInfo]] = deque()  #: guarded-by _cq_lock,_cq_cv
        self._cq_lock = threading.Lock()
        self._cq_cv = threading.Condition(self._cq_lock)
        # deadline-tracked operations: (deadline, cancel_fn) — checked in progress
        self._deadlines: list = []  #: guarded-by _deadline_lock
        self._deadline_lock = threading.Lock()

    # -- completion queue ----------------------------------------------------
    def completion_add(self, cb: Optional[Callback], info: CallbackInfo) -> None:
        with self._cq_cv:
            self._cq.append((cb, info))
            self._cq_cv.notify_all()
        # wake a progress() blocked inside the NA plugin
        self.na.interrupt()

    def completion_count(self) -> int:
        with self._cq_lock:
            return len(self._cq)

    # -- deadlines -------------------------------------------------------------
    def add_deadline(self, deadline: float, on_timeout: Callable[[], None]) -> dict:
        entry = {"deadline": deadline, "fire": on_timeout, "armed": True}
        with self._deadline_lock:
            self._deadlines.append(entry)
        return entry

    def disarm(self, entry: dict) -> None:
        entry["armed"] = False

    def _check_deadlines(self) -> None:
        now = time.monotonic()
        fired = []
        with self._deadline_lock:
            keep = []
            for e in self._deadlines:
                if not e["armed"]:
                    continue
                if e["deadline"] <= now:
                    fired.append(e)
                else:
                    keep.append(e)
            self._deadlines = keep
        for e in fired:
            e["fire"]()

    # -- progress / trigger ------------------------------------------------------
    def progress(self, timeout: float = 0.0) -> Ret:
        """Drive the NA transport. Returns SUCCESS once the completion queue
        is non-empty, TIMEOUT otherwise (Mercury HG_Progress semantics)."""
        deadline = time.monotonic() + timeout
        while True:
            self._check_deadlines()
            if self.completion_count():
                return Ret.SUCCESS
            remaining = deadline - time.monotonic()
            step = min(max(remaining, 0.0), 0.05)
            self.na.progress(step)
            if self.completion_count():
                return Ret.SUCCESS
            if time.monotonic() >= deadline:
                return Ret.TIMEOUT

    def trigger(self, max_count: int = 2 ** 31, timeout: float = 0.0) -> int:
        """Execute up to ``max_count`` queued callbacks; returns the number
        actually executed."""
        executed = 0
        deadline = time.monotonic() + timeout
        while executed < max_count:
            with self._cq_cv:
                if not self._cq:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cq_cv.wait(remaining)
                    if not self._cq:
                        break
                cb, info = self._cq.popleft()
            if cb is not None:
                cb(info)
            executed += 1
        return executed

    def progress_trigger(self, timeout: float = 0.1) -> int:
        """Convenience: one progress pass + drain (single-threaded pattern)."""
        self.progress(timeout)
        return self.trigger()
