"""Executor shims (paper C5, second half).

The paper: the callback/completion-queue core "allows definition ... of
shim layers that simplify common cases, based for instance on a request
model to provide post/test operations" and "a multithreaded execution
model". Both are built here *on top of* the unchanged core:

  * :class:`Engine` — owns an HGClass; a daemon *progress thread* spins
    ``progress``; triggered callbacks dispatch RPC handlers onto a
    thread-pool (multithreaded execution model).
  * :meth:`Engine.call` / :meth:`Engine.call_async` — request-model shim
    (post/wait → blocking call; post/test → Future).
  * Bulk helpers (``expose`` / ``pull`` / ``push``) — one-call wrappers
    over the bulk layer with blocking semantics for handler code.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import threading
import time
from typing import Any, Callable, Dict, Optional, Sequence

import numpy as np

from . import proc as hg_proc
from .bulk import (BulkDescriptor, BulkHandle, BulkOp, BulkOpType,
                   bulk_transfer, expose_arrays)
from .na import initialize
from .na.base import NAAddress, NAPlugin
from .progress import Context
from .rpc import Handle, HGClass
from .types import CallbackInfo, MercuryError, OpType, Ret
from ..telemetry import metrics as _metrics
from ..telemetry import trace as _trace

_M_CALLS = _metrics.counter("core.engine.calls")
_M_HANDLED = _metrics.counter("core.engine.handled")
_M_NOTIFIES = _metrics.counter("core.engine.notifies")


class RemoteError(MercuryError):
    """Raised at the origin when the target handler faulted."""

    def __init__(self, ret: Ret, detail: str = ""):
        super().__init__(ret, detail)
        self.detail = detail


class CallFuture(cf.Future):
    """Future returned by :meth:`Engine.call_async`; carries the underlying
    RPC handle so callers (hedged requests, pools) can abandon the call."""

    handle: Optional[Handle] = None

    def cancel_call(self) -> None:
        """Cancel the in-flight RPC; the future resolves with a
        ``Ret.CANCELED`` :class:`RemoteError` (unless the response won the
        race, in which case the result stands)."""
        if self.handle is not None:
            self.handle.cancel()


class Engine:
    """A service node runtime: progress thread + handler pool + call shims.

    Every Engine is simultaneously an origin and a target (paper C4): it
    can ``register`` handlers and ``call`` remote ones.
    """

    def __init__(self, uri: Optional[str | Sequence[str]] = None,
                 listen: bool = True,
                 handler_threads: int = 4, checksum: bool = True,
                 progress_interval: float = 0.05, copy_local: bool = True,
                 local_dispatch: bool = True):
        """``uri`` may be one transport URI, a semicolon-joined address set
        (``"self://a;sm://a;tcp://127.0.0.1:0"``) or a list of URIs; multi-
        transport engines resolve each target to its cheapest tier.

        ``local_dispatch``/``copy_local`` tune the self-tier fast path
        (DESIGN.md §9): co-located calls skip serialization entirely;
        ``copy_local=False`` (with ``checksum=False`` on both ends)
        additionally shares values zero-copy instead of deep-copying."""
        self.na: NAPlugin = initialize(uri, listen=listen)
        self.hg = HGClass(self.na, checksum_payloads=checksum,
                          copy_local=copy_local,
                          local_dispatch=local_dispatch)
        self.ctx: Context = self.hg.context
        self._pool = cf.ThreadPoolExecutor(max_workers=handler_threads,
                                           thread_name_prefix="hg-handler")
        self._stop = threading.Event()
        self._progress_interval = progress_interval
        self._addr_cache: Dict[str, NAAddress] = {}
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"hg-progress[{self.uri}]")
        if listen:
            self.hg.listen()
            self._register_telemetry_rpcs()
        self._thread.start()

    def _register_telemetry_rpcs(self) -> None:
        """Every listening engine serves the telemetry plane uniformly:
        ``dbg.trace`` (span ring snapshot — clients reassemble the
        cross-process span tree by unioning these) and ``fab.metrics``
        (the process-global metrics registry)."""
        self.register(
            "dbg.trace",
            lambda req: _trace.export(trace_id=(req or {}).get("trace_id"),
                                      limit=(req or {}).get("limit")),
            inline=True)
        self.register(
            "fab.metrics",
            lambda _req: {"pid": os.getpid(), "uri": self.uri,
                          "metrics": _metrics.snapshot()},
            inline=True)

    # ------------------------------------------------------------------ runtime
    @property
    def uri(self) -> str:
        return self.na.addr_self().uri

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.ctx.progress(self._progress_interval)
                # Trigger everything currently queued. RPC handler entries
                # hop to the pool inside their wrapper (see register()).
                self.ctx.trigger()
            except Exception:
                if self._stop.is_set():
                    return
                import traceback
                traceback.print_exc()

    def shutdown(self) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self.na.interrupt()
        self._thread.join(timeout=2.0)
        self._pool.shutdown(wait=False)
        self.hg.finalize()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    # ------------------------------------------------------------------ target
    def register(self, name: str, fn: Callable[..., Any],
                 in_proc: hg_proc.Proc = hg_proc.proc_any,
                 out_proc: hg_proc.Proc = hg_proc.proc_any,
                 no_response: bool = False,
                 pass_handle: bool = False,
                 inline: bool = False) -> None:
        """Register ``fn(input) -> output`` as an RPC handler.  By default
        the handler hops to the thread pool (safe for blocking work);
        ``inline=True`` executes it directly on the progress thread — the
        low-latency path for cheap, non-blocking handlers (the handler
        MUST NOT block or issue nested blocking RPCs).

        Every handler execution is a *server span* of the wire-propagated
        trace (no-op unless the request carried a sampled context), and
        the request's context is installed as the thread's ambient context
        for the handler's duration — nested calls (service chains, the
        registry's write-proxy hop) inherit it automatically."""

        def handler(handle: Handle) -> None:
            def work():
                _M_HANDLED.inc()
                span = _trace.start_span(f"rpc.{name}", handle.trace_ctx)
                if span.recorded:
                    span.annotate(
                        engine=self.uri, budget_ms=handle.budget_ms,
                        queue_ms=round(
                            (time.monotonic() - handle.arrived) * 1e3, 3),
                        local=handle._local_deliver is not None)
                tok = _trace.activate(span.ctx)
                status = "OK"
                try:
                    value = handle.get_input()
                    if pass_handle:
                        out = fn(value, handle)
                        if handle.responded or handle.deferred or no_response:
                            return
                    else:
                        out = fn(value)
                    if not no_response:
                        handle.respond(out)
                except MercuryError as e:
                    status = e.ret.name
                    if not no_response and not handle.responded:
                        handle.respond(str(e), ret=e.ret)
                except Exception as e:
                    status = "FAULT"
                    if not no_response and not handle.responded:
                        handle.respond(f"{type(e).__name__}: {e}", ret=Ret.FAULT)
                finally:
                    _trace.restore(tok)
                    span.finish(status)
            if inline:
                work()
            else:
                self._pool.submit(work)

        self.hg.register(name, in_proc, out_proc, handler,
                         no_response=no_response)

    # ------------------------------------------------------------------ origin
    def lookup(self, uri: str) -> NAAddress:
        addr = self._addr_cache.get(uri)
        if addr is None:
            addr = self.hg.lookup(uri)
            self._addr_cache[uri] = addr
        return addr

    def _ensure_registered(self, name: str) -> None:
        # Origin side only needs procs; default proc_any if unseen.
        if not self.hg.is_registered(name):
            self.hg.register(name)

    def call_async(self, target: str | NAAddress, name: str, arg: Any = None,
                   timeout: Optional[float] = 30.0,
                   deadline: Optional[float] = None) -> CallFuture:
        """Post an RPC; resolve a Future with the decoded output.

        ``deadline`` (absolute ``time.monotonic()`` value) overrides
        ``timeout``: the transport timeout becomes the time remaining, and
        an already-expired deadline fails fast without touching the wire.
        The returned :class:`CallFuture` supports ``cancel_call()``.
        """
        fut = CallFuture()
        _M_CALLS.inc()
        if deadline is not None:
            timeout = deadline - time.monotonic()
            if timeout <= 0:
                fut.set_exception(RemoteError(Ret.TIMEOUT,
                                              f"{name}: deadline expired"))
                return fut
        self._ensure_registered(name)
        addr = self.lookup(target) if isinstance(target, str) else target
        handle = self.hg.create(addr, name)
        fut.handle = handle

        def on_complete(info: CallbackInfo):
            h: Handle = info.handle
            if info.ret != Ret.SUCCESS or h.ret != Ret.SUCCESS:
                ret = info.ret if info.ret != Ret.SUCCESS else h.ret
                detail = str(h.output) if h.output else name
                fut.set_exception(RemoteError(ret, detail))
            else:
                fut.set_result(h.output)

        handle.forward(arg, on_complete, timeout=timeout)
        return fut

    def call(self, target: str | NAAddress, name: str, arg: Any = None,
             timeout: Optional[float] = 30.0,
             deadline: Optional[float] = None) -> Any:
        """Blocking request-model shim (post/wait)."""
        if deadline is not None:
            # pass through: an already-expired deadline fails fast inside
            # call_async without putting the request on the wire
            fut = self.call_async(target, name, arg, deadline=deadline)
            grace = max(deadline - time.monotonic(), 0.0) + 5.0
            return fut.result(timeout=grace)
        fut = self.call_async(target, name, arg, timeout=timeout)
        # +grace so transport-level timeout fires first with a precise code
        return fut.result(timeout=None if timeout is None else timeout + 5.0)

    def notify(self, target: str | NAAddress, name: str, arg: Any = None) -> None:
        """Fire-and-forget RPC (NO_RESPONSE flag)."""
        _M_NOTIFIES.inc()
        if not self.hg.is_registered(name):
            self.hg.register(name, no_response=True)
        addr = self.lookup(target) if isinstance(target, str) else target
        handle = self.hg.create(addr, name)
        handle.forward(None if arg is None else arg, None)

    # ------------------------------------------------------------------ bulk
    def expose(self, arrays: Sequence[np.ndarray], read: bool = True,
               write: bool = True) -> BulkHandle:
        return expose_arrays(self.na, arrays, read=read, write=write)

    def _transfer(self, op: BulkOpType, origin: str | NAAddress,
                  desc: BulkDescriptor, local: BulkHandle,
                  remote_offset: int = 0, local_offset: int = 0,
                  size: Optional[int] = None, timeout: float = 60.0,
                  chunk_size: int = 4 * 1024 * 1024,
                  max_inflight: int = 4) -> None:
        if size is None:
            size = min(desc.size - remote_offset, local.size - local_offset)
        addr = self.lookup(origin) if isinstance(origin, str) else origin
        done = threading.Event()
        box = {}

        def cb(info: CallbackInfo):
            box["ret"] = info.ret
            done.set()

        bulk_transfer(self.ctx, op, addr, desc, remote_offset, local,
                      local_offset, size, cb, chunk_size=chunk_size,
                      max_inflight=max_inflight)
        if not done.wait(timeout):
            raise MercuryError(Ret.TIMEOUT, "bulk transfer timed out")
        if box["ret"] != Ret.SUCCESS:
            raise MercuryError(box["ret"], "bulk transfer failed")

    def pull(self, origin: str | NAAddress, desc: BulkDescriptor,
             local: BulkHandle, **kw) -> None:
        """One-sided GET: remote (descriptor) → local handle."""
        self._transfer(BulkOpType.GET, origin, desc, local, **kw)

    def push(self, origin: str | NAAddress, desc: BulkDescriptor,
             local: BulkHandle, **kw) -> None:
        """One-sided PUT: local handle → remote (descriptor)."""
        self._transfer(BulkOpType.PUT, origin, desc, local, **kw)
