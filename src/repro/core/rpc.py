"""RPC core (paper contribution C2) — Mercury hg_core equivalent.

An RPC operation is deliberately *lightweight*: a buffer transmitted to a
target where a registered function callback is executed. Dispatch is by a
stable 64-bit id derived from the RPC name (both sides register the same
name). Origin and target are symmetric (C4): every :class:`HGClass` can
both forward and serve.

Flow (matches Mercury):
  origin:  handle = hg.create(addr, id)
           handle.forward(input, cb)      # encode → unexpected msg
                                          # + pre-posted expected recv(cookie)
  target:  unexpected msg → decode header → look up id
           → RPC_HANDLER completion entry on the context queue
           trigger() → handler(handle); handler: handle.get_input(),
           work (may issue bulk transfers), handle.respond(output)
  origin:  expected msg(cookie) → FORWARD completion entry → cb(info)
"""
from __future__ import annotations

import copy as _copy
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import numpy as np

from . import proc as hg_proc
from .bulk import BulkDescriptor, BulkHandle, BulkOpType, bulk_transfer
from .na.base import NAAddress, NAPlugin, UNEXPECTED_MSG_LIMIT
from .progress import Context
from .types import (Callback, CallbackInfo, Flags, MercuryError, OpType,
                    PROTOCOL_VERSION, REQUEST_HEADER_SIZE,
                    RESPONSE_HEADER_SIZE, RequestHeader, ResponseHeader, Ret,
                    ZERO_TRACE_ID, _Counter, payload_crc32, stable_rpc_id)
from ..telemetry import trace as _trace


# Serialization-free self-tier dispatch (DESIGN.md §9): every listening
# HGClass registers here under each of its SAME_PROCESS (self-tier) URIs.
# An origin forwarding to one of these URIs hands the request/response
# *values* across directly — no proc encode/decode, no header round trip —
# while keeping identical Ret/cancel/deadline semantics.
_LOCAL_DISPATCH: Dict[str, "HGClass"] = {}  #: guarded-by _LOCAL_LOCK
_LOCAL_LOCK = threading.Lock()


def _local_target(uri: str) -> Optional["HGClass"]:
    if not uri.startswith("self://"):
        return None
    with _LOCAL_LOCK:
        hg = _LOCAL_DISPATCH.get(uri)
    return hg if hg is not None and hg._listening else None


@dataclass
class RPCInfo:
    name: str
    rpc_id: int
    in_proc: hg_proc.Proc
    out_proc: hg_proc.Proc
    handler: Optional[Callable[["Handle"], None]]
    no_response: bool = False


class HandleInfo:
    """hg_info: addressing info attached to a handle."""

    __slots__ = ("addr", "rpc_id", "context")

    def __init__(self, addr: NAAddress, rpc_id: int, context: Context):
        self.addr = addr
        self.rpc_id = rpc_id
        self.context = context


class Handle:
    """An RPC handle — origin side (created via HGClass.create) or target
    side (materialized by the dispatcher for an incoming request)."""

    def __init__(self, hg: "HGClass", info: HandleInfo, rpc: RPCInfo):
        self.hg = hg
        self.info = info
        self.rpc = rpc
        self.cookie: int = 0
        self.ret: Ret = Ret.SUCCESS
        self.output: Any = None          # origin: decoded response
        self._input_raw: Optional[memoryview] = None
        self._input: Any = None
        self._input_decoded = False
        self._payload_bulk: Optional[BulkHandle] = None
        self._payload_staged = None     # shm staging buffer (sm rendezvous)
        self._deadline_entry: Optional[dict] = None
        self._recv_op = None
        self._complete: Optional[Callable[..., None]] = None
        self._completed = False  #: guarded-by _lock
        # target side, self-tier fast path: set by the origin's
        # _forward_local so respond() hands the output value straight back
        # (no encode / expected-message send)
        self._local_deliver: Optional[Callable[..., None]] = None
        self._lock = threading.Lock()
        self.responded = False
        # target side: a pass_handle handler sets this before returning to
        # take ownership of responding later (event-driven response)
        self.deferred = False
        # target side: caller's remaining deadline budget (header field) and
        # local arrival time — admission control reads these via
        # remaining_budget()
        self.budget_ms: int = 0
        self.arrived: float = 0.0
        # target side: wire-propagated trace context (v5 header) and the
        # peer's protocol version (echoed in the response header so v4
        # peers keep decoding us)
        self.trace_ctx: Optional[_trace.TraceContext] = None
        self.peer_version: int = PROTOCOL_VERSION

    def _release_payload(self) -> None:
        if self._payload_bulk is not None:
            self._payload_bulk.free()
            self._payload_bulk = None
        if self._payload_staged is not None:
            self.hg.na.free_msg_buffer(self._payload_staged)
            self._payload_staged = None

    # ------------------------------------------------------------------ origin
    def forward(self, input_value: Any, cb: Optional[Callback] = None,
                timeout: Optional[float] = None, arg: Any = None) -> None:
        """Issue the RPC (non-blocking). ``cb`` fires from trigger() when the
        response (or failure/timeout) is known.

        ``timeout`` doubles as the caller's *deadline budget*: it rides the
        request header (``budget_ms``) so the target can make admission
        decisions against the time the caller is actually willing to wait."""
        hg = self.hg
        if hg.local_dispatch:
            thg = _local_target(self.info.addr.uri)
            if thg is not None and thg.local_dispatch:
                self._forward_local(thg, input_value, cb, timeout, arg)
                return
        ctx = self.info.context
        self.cookie = hg._cookie_counter.next()
        payload = hg_proc.encode(self.rpc.in_proc, input_value)
        budget_ms = 0
        if timeout is not None and timeout > 0:
            # round sub-millisecond budgets UP to 1: 0 means "no
            # deadline" on the wire, and a nearly-expired caller is the
            # one admission control most needs to know about
            budget_ms = min(max(int(timeout * 1e3), 1), 0xFFFFFFFF)
        flags = Flags.NONE
        crc = 0
        if hg.checksum_payloads:
            flags |= Flags.CHECKSUM
            crc = payload_crc32(payload)
        if self.rpc.no_response:
            flags |= Flags.NO_RESPONSE
        # ambient trace context rides the v5 header (one TLS read when
        # untraced — the near-zero unsampled path)
        tctx = _trace.current()
        if tctx is not None:
            t_id, s_id, t_fl = tctx.trace_id, tctx.span_id, tctx.flags
        else:
            t_id, s_id, t_fl = ZERO_TRACE_ID, 0, 0
        limit = getattr(hg.na, "max_unexpected_size", UNEXPECTED_MSG_LIMIT)
        if REQUEST_HEADER_SIZE + len(payload) > limit:
            # Rendezvous: the unexpected message carries only a bulk
            # descriptor; the target pulls the payload one-sidedly (a
            # single zero-copy on plugins with native RMA).
            if self.rpc.no_response:
                raise MercuryError(
                    Ret.MSGSIZE,
                    f"NO_RESPONSE rpc payload {len(payload)}B exceeds the "
                    f"eager limit {limit}B; origin cannot learn when the "
                    f"pull finished")
            flags |= Flags.RENDEZVOUS
            # transports whose RMA needs special memory (sm: cross-process
            # pulls only reach shm-backed registrations) stage the payload
            staged = hg.na.alloc_msg_buffer(len(payload))
            if staged is not None:
                staged[:len(payload)] = np.frombuffer(payload, np.uint8)
                self._payload_staged = staged
                reg_buf = staged[:len(payload)]
            else:
                reg_buf = np.frombuffer(payload, np.uint8)
            self._payload_bulk = BulkHandle(hg.na, [reg_buf],
                                            read=True, write=False)
            hdr = RequestHeader(self.rpc.rpc_id, self.cookie, flags,
                                len(payload), crc, budget_ms,
                                t_id, s_id, t_fl)
            msg = (hdr.pack(), self._payload_bulk.descriptor().to_bytes())
        else:
            hdr = RequestHeader(self.rpc.rpc_id, self.cookie, flags,
                                len(payload), crc, budget_ms,
                                t_id, s_id, t_fl)
            msg = (hdr.pack(), payload)   # vectored: no payload copy

        def complete(ret: Ret, output: Any = None):
            with self._lock:
                if self._completed:
                    return
                self._completed = True
            self._release_payload()
            self.ret = ret
            self.output = output
            if self._deadline_entry is not None:
                ctx.disarm(self._deadline_entry)
            ctx.completion_add(cb, CallbackInfo(OpType.FORWARD, ret,
                                                handle=self, arg=arg))

        self._complete = complete

        if not self.rpc.no_response:
            def on_response(ret: Ret, data: memoryview):
                if ret != Ret.SUCCESS:
                    complete(ret)
                    return
                try:
                    rhdr = ResponseHeader.unpack(data)
                    body = data[RESPONSE_HEADER_SIZE:]
                    if rhdr.payload_len and Flags.CHECKSUM and hg.checksum_payloads:
                        if rhdr.payload_crc and payload_crc32(body) != rhdr.payload_crc:
                            complete(Ret.CHECKSUM_ERROR)
                            return
                    if rhdr.ret != Ret.SUCCESS:
                        out = None
                        if rhdr.payload_len:
                            out = hg_proc.decode(hg_proc.proc_str, body)
                        complete(rhdr.ret, out)
                        return
                    out = hg_proc.decode(self.rpc.out_proc, body) \
                        if rhdr.payload_len else None
                    complete(Ret.SUCCESS, out)
                except MercuryError as e:
                    complete(e.ret)
                except Exception:
                    complete(Ret.PROTOCOL_ERROR)

            self._recv_op = hg.na.msg_recv_expected(self.info.addr, self.cookie,
                                                    on_response)
            if timeout is not None:
                def on_timeout():
                    if self._recv_op is not None:
                        hg.na.cancel(self._recv_op)
                    complete(Ret.TIMEOUT)
                self._deadline_entry = ctx.add_deadline(
                    time.monotonic() + timeout, on_timeout)

        def on_sent(ret: Ret):
            if ret != Ret.SUCCESS:
                if self._recv_op is not None:
                    hg.na.cancel(self._recv_op)
                complete(ret)
            elif self.rpc.no_response:
                complete(Ret.SUCCESS)

        hg.na.msg_send_unexpected(self.info.addr, msg, self.cookie, on_sent)

    def _forward_local(self, thg: "HGClass", input_value: Any,
                       cb: Optional[Callback], timeout: Optional[float],
                       arg: Any) -> None:
        """Self-tier fast path (DESIGN.md §9): origin and target share this
        process, so the request/response *values* are handed across
        directly — no proc encode/decode, no header pack/unpack, no
        progress-thread round trip.  Semantics match the wire path: same
        Ret codes, exactly-once completion, and cancel()/deadline behave
        identically (a response racing a cancel wins whichever grabs the
        completion lock first).

        Value isolation: the wire path serializes, so mutations on either
        side never alias.  That guarantee is kept by deep-copying the
        values unless *both* classes opted out (``copy_local=False`` with
        checksums off)."""
        hg = self.hg
        ctx = self.info.context
        self.cookie = hg._cookie_counter.next()
        budget_ms = 0
        if timeout is not None and timeout > 0:
            budget_ms = min(max(int(timeout * 1e3), 1), 0xFFFFFFFF)
        copy = (hg.checksum_payloads or hg.copy_local
                or thg.checksum_payloads or thg.copy_local)

        def complete(ret: Ret, output: Any = None):
            with self._lock:
                if self._completed:
                    return
                self._completed = True
            self.ret = ret
            self.output = output
            if self._deadline_entry is not None:
                ctx.disarm(self._deadline_entry)
            if cb is not None:
                cb(CallbackInfo(OpType.FORWARD, ret, handle=self, arg=arg))

        self._complete = complete

        tinfo = thg.registered.get(self.rpc.rpc_id)
        if tinfo is None or tinfo.handler is None:
            complete(Ret.SUCCESS if self.rpc.no_response else Ret.NOENTRY)
            return

        if timeout is not None and not self.rpc.no_response:
            self._deadline_entry = ctx.add_deadline(
                time.monotonic() + timeout, lambda: complete(Ret.TIMEOUT))

        # reply-to address for the target handle (origin/target symmetry:
        # the handler may forward back to us through the same machinery)
        local = hg.na.local_uris()
        origin_addr = self.info.addr
        if local:
            try:
                origin_addr = thg.na.addr_lookup(local[0])
            except MercuryError:
                pass

        th = Handle(thg, HandleInfo(origin_addr, self.rpc.rpc_id,
                                    thg.context), tinfo)
        th.cookie = self.cookie
        th.budget_ms = budget_ms
        th.arrived = time.monotonic()
        # self-tier: the trace context object is handed across directly —
        # no serialization, matching the value fast path it instruments
        th.trace_ctx = _trace.current()
        th._input = _copy.deepcopy(input_value) if copy else input_value
        th._input_decoded = True

        def deliver(ret: Ret, output: Any):
            if ret == Ret.SUCCESS:
                complete(Ret.SUCCESS,
                         _copy.deepcopy(output) if copy else output)
            else:
                # wire parity: error responses carry only str(output)
                complete(ret, None if output is None else str(output))

        th._local_deliver = deliver

        if self.rpc.no_response:
            # fire-and-forget: "handed over" is what SUCCESS means on the
            # wire path too (send completion, not handler completion)
            complete(Ret.SUCCESS)

        # The handler runs on the calling thread; Engine-registered
        # non-inline handlers immediately hop to the worker pool, so slow
        # work never blocks forward() (and deadlines still fire from the
        # progress thread).  Error mapping mirrors _dispatch's run().
        try:
            tinfo.handler(th)
        except MercuryError as e:
            if not tinfo.no_response and not th.responded:
                th.respond(str(e), ret=e.ret)
        except Exception as e:
            if not tinfo.no_response and not th.responded:
                th.respond(f"{type(e).__name__}: {e}", ret=Ret.FAULT)

    def cancel(self) -> None:
        """Cancel an in-flight forward.  The forward's completion callback
        fires with ``Ret.CANCELED`` (exactly once — a response racing the
        cancel wins whichever grabs the completion lock first), so futures
        layered on top always resolve; this is what lets hedged requests
        abandon the loser."""
        if self._recv_op is not None:
            self.hg.na.cancel(self._recv_op)
        if self._complete is not None:
            self._complete(Ret.CANCELED)
            return
        # not forwarded yet: mark completed so a later forward is a no-op
        with self._lock:
            if self._completed:
                return
            self._completed = True
        self._release_payload()
        self.ret = Ret.CANCELED
        if self._deadline_entry is not None:
            self.info.context.disarm(self._deadline_entry)

    # ------------------------------------------------------------------ target
    def remaining_budget(self) -> Optional[float]:
        """Seconds left of the caller's deadline budget (header
        ``budget_ms`` minus the time this request has already spent on the
        target), or ``None`` if the caller set no deadline.  Never
        negative: an already-blown budget reads 0.0."""
        if not self.budget_ms:
            return None
        return max(self.budget_ms / 1e3 - (time.monotonic() - self.arrived),
                   0.0)

    def get_input(self) -> Any:
        if not self._input_decoded:
            self._input = hg_proc.decode(self.rpc.in_proc, self._input_raw)
            self._input_decoded = True
        return self._input

    def respond(self, output: Any = None, ret: Ret = Ret.SUCCESS,
                cb: Optional[Callback] = None) -> None:
        if self.rpc.no_response:
            raise MercuryError(Ret.INVALID_ARG, "RPC registered as NO_RESPONSE")
        if self.responded:
            raise MercuryError(Ret.INVALID_ARG, "respond() called twice")
        if self._local_deliver is not None:
            # self-tier fast path: hand the output value straight to the
            # origin's completion (no encode, no expected-message send)
            self.responded = True
            self._local_deliver(ret, output)
            if cb is not None:
                cb(CallbackInfo(OpType.RESPOND, Ret.SUCCESS, handle=self))
            return
        hg = self.hg
        if ret == Ret.SUCCESS:
            payload = hg_proc.encode(self.rpc.out_proc, output) \
                if output is not None else b""
        else:
            payload = hg_proc.encode(hg_proc.proc_str, str(output)) \
                if output is not None else b""
        crc = payload_crc32(payload) if hg.checksum_payloads and payload else 0
        hdr = ResponseHeader(self.cookie, ret, len(payload), crc,
                             version=self.peer_version)

        ctx = self.info.context

        def on_sent(send_ret: Ret):
            ctx.completion_add(cb, CallbackInfo(OpType.RESPOND, send_ret,
                                                handle=self))

        # may raise MSGSIZE: leave ``responded`` unset so the handler's
        # error path can still send a (small) failure response
        hg.na.msg_send_expected(self.info.addr, (hdr.pack(), payload),
                                self.cookie, on_sent)
        self.responded = True


class HGClass:
    """Top-level RPC class: owns the NA plugin, the registration table and
    the default execution context (more can be created)."""

    def __init__(self, na: NAPlugin, checksum_payloads: bool = True,
                 unexpected_prepost: int = 8, copy_local: bool = True,
                 local_dispatch: bool = True):
        self.na = na
        self.checksum_payloads = checksum_payloads
        # Self-tier fast path knobs (DESIGN.md §9): ``local_dispatch``
        # gates the serialization-free in-process path entirely;
        # ``copy_local`` keeps wire-equivalent value isolation on it
        # (deep-copy request/response values).  ``copy_local=False`` with
        # checksums off on both sides yields true zero-copy handoff —
        # caller and handler then share the objects.
        self.copy_local = copy_local
        self.local_dispatch = local_dispatch
        self.registered: Dict[int, RPCInfo] = {}
        self._by_name: Dict[str, RPCInfo] = {}
        self._cookie_counter = _Counter()
        self.context = Context(na)
        self._unexpected_prepost = unexpected_prepost
        self._listening = False
        self._local_uris: list = []

    # -- registration -----------------------------------------------------------
    def register(self, name: str,
                 in_proc: hg_proc.Proc = hg_proc.proc_any,
                 out_proc: hg_proc.Proc = hg_proc.proc_any,
                 handler: Optional[Callable[[Handle], None]] = None,
                 no_response: bool = False) -> int:
        rpc_id = stable_rpc_id(name)
        info = RPCInfo(name, rpc_id, in_proc, out_proc, handler, no_response)
        existing = self.registered.get(rpc_id)
        if existing is not None and existing.name != name:
            raise MercuryError(Ret.INVALID_ARG,
                               f"rpc id collision: {name} vs {existing.name}")
        self.registered[rpc_id] = info
        self._by_name[name] = info
        return rpc_id

    def is_registered(self, name: str) -> bool:
        return name in self._by_name

    def lookup(self, uri: str) -> NAAddress:
        return self.na.addr_lookup(uri)

    def addr_self(self) -> NAAddress:
        return self.na.addr_self()

    # -- origin side --------------------------------------------------------------
    def create(self, addr: NAAddress, name: str) -> Handle:
        info = self._by_name.get(name)
        if info is None:
            raise MercuryError(Ret.NOENTRY, f"rpc not registered: {name}")
        return Handle(self, HandleInfo(addr, info.rpc_id, self.context), info)

    # -- target side ----------------------------------------------------------------
    def listen(self) -> None:
        """Arm the dispatcher: pre-post unexpected receives (re-posted on
        each arrival so there are always ``unexpected_prepost`` armed)."""
        if self._listening:
            return
        self._listening = True
        if self.local_dispatch:
            uris = self.na.local_uris()
            with _LOCAL_LOCK:
                for u in uris:
                    _LOCAL_DISPATCH[u] = self
            self._local_uris = uris
        for _ in range(self._unexpected_prepost):
            self._post_unexpected()

    def _post_unexpected(self) -> None:
        self.na.msg_recv_unexpected(self._on_unexpected)

    def _on_unexpected(self, ret: Ret, source: NAAddress, tag: int,
                       data: memoryview) -> None:
        # keep the pipeline of posted receives full
        if self._listening:
            self._post_unexpected()
        if ret != Ret.SUCCESS:
            return
        try:
            hdr = RequestHeader.unpack(data)
        except MercuryError:
            return
        # v4 peers send the shorter legacy header: slice the body at the
        # *decoded* header size, never at the v5 constant
        body = data[hdr.wire_size:]
        info = self.registered.get(hdr.rpc_id)

        if info is None:
            if not (hdr.flags & Flags.NO_RESPONSE):
                rhdr = ResponseHeader(hdr.cookie, Ret.NOENTRY, 0, 0,
                                      version=hdr.version)
                self.na.msg_send_expected(source, rhdr.pack(), hdr.cookie,
                                          lambda r: None)
            return

        if hdr.flags & Flags.RENDEZVOUS:
            self._pull_then_dispatch(info, hdr, source, body)
        else:
            self._dispatch(info, hdr, source, body)

    def _pull_then_dispatch(self, info: RPCInfo, hdr: RequestHeader,
                            source: NAAddress, desc_bytes: memoryview) -> None:
        """Oversized request: the body is a bulk descriptor — pull the real
        payload one-sidedly (zero-copy on native-RMA plugins), then proceed
        exactly as the eager path."""

        def fail(ret: Ret) -> None:
            if not (hdr.flags & Flags.NO_RESPONSE):
                rhdr = ResponseHeader(hdr.cookie, ret, 0, 0,
                                      version=hdr.version)
                self.na.msg_send_expected(source, rhdr.pack(), hdr.cookie,
                                          lambda r: None)

        try:
            desc = BulkDescriptor.from_bytes(desc_bytes)
        except Exception:
            fail(Ret.PROTOCOL_ERROR)
            return
        # the descriptor is peer-controlled: allocate only what the header
        # declared, and refuse disagreement instead of trusting desc.size
        if desc.size != hdr.payload_len:
            fail(Ret.PROTOCOL_ERROR)
            return
        try:
            buf = bytearray(desc.size)
            lh = BulkHandle(self.na, [buf], read=True, write=True)
        except (MemoryError, MercuryError):
            fail(Ret.NOMEM)
            return

        def on_pulled(cbinfo: CallbackInfo):
            lh.free()
            if cbinfo.ret != Ret.SUCCESS:
                fail(cbinfo.ret)
                return
            self._dispatch(info, hdr, source, memoryview(buf))

        try:
            # a plugin may raise synchronously from put/get (sm does for
            # unreachable registrations) — keep that off the progress thread
            bulk_transfer(self.context, BulkOpType.GET, source, desc, 0, lh,
                          0, desc.size, on_pulled)
        except MercuryError as e:
            lh.free()
            fail(e.ret)

    def _dispatch(self, info: RPCInfo, hdr: RequestHeader, source: NAAddress,
                  body: memoryview) -> None:
        handle = Handle(self, HandleInfo(source, hdr.rpc_id, self.context), info)
        handle.cookie = hdr.cookie
        handle._input_raw = body
        handle.budget_ms = hdr.budget_ms
        handle.arrived = time.monotonic()
        handle.peer_version = hdr.version
        if hdr.span_id:
            handle.trace_ctx = _trace.TraceContext(
                hdr.trace_id, hdr.span_id, hdr.trace_flags)

        if (hdr.flags & Flags.CHECKSUM) and self.checksum_payloads and hdr.payload_len:
            if payload_crc32(body) != hdr.payload_crc:
                if not (hdr.flags & Flags.NO_RESPONSE):
                    handle.respond(None, ret=Ret.CHECKSUM_ERROR)
                return

        if info.handler is None:
            if not (hdr.flags & Flags.NO_RESPONSE):
                handle.respond(None, ret=Ret.NOENTRY)
            return

        # Paper C5: the handler callback is *placed onto the completion
        # queue* before being executed (by trigger()).
        def run(_info: CallbackInfo):
            try:
                info.handler(handle)
            except MercuryError as e:
                if not info.no_response and not handle.responded:
                    handle.respond(str(e), ret=e.ret)
            except Exception as e:  # handler fault → FAULT response
                if not info.no_response and not handle.responded:
                    handle.respond(f"{type(e).__name__}: {e}", ret=Ret.FAULT)

        self.context.completion_add(
            run, CallbackInfo(OpType.RPC_HANDLER, Ret.SUCCESS, handle=handle))

    def finalize(self) -> None:
        self._listening = False
        if self._local_uris:
            with _LOCAL_LOCK:
                for u in self._local_uris:
                    if _LOCAL_DISPATCH.get(u) is self:
                        del _LOCAL_DISPATCH[u]
            self._local_uris = []
        self.na.finalize()
