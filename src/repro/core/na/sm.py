"""``sm`` NA plugin — same-host shared-memory transport.

Two-sided messaging runs over per-connection SPSC byte rings living in
``multiprocessing.shared_memory`` segments; a named-FIFO doorbell per
instance gives blocking ``progress()`` without busy-polling.  One-sided
RMA is *native* (NACap.NATIVE_RMA | ZERO_COPY): ``put``/``get`` are a
single direct copy into the destination buffer, performed entirely by the
initiator — the target's progress loop is never involved:

  * peer in this process  → copy via the process-local instance registry;
  * peer in another process → the owner published the registration in the
    *memdir* table of its control segment (key → segment name/offset);
    the initiator attaches that segment and copies.

Cross-process RMA therefore requires shm-backed registered memory — use
:meth:`SMPlugin.alloc_array` — while plain ndarrays still get zero-copy
RMA against peers in the same process.  See DESIGN.md §4.

Wire layout (all little-endian):
  control segment  — magic | uri | peer-slot table | memdir
  conn segment     — magic | connector uri | ring A→B | ring B→A
  ring             — head u64 | tail u64 | producer-waiting u8 | data
  frame            — total u32 | kind u8 | tag u64 | payload

Connection setup: the connector creates the conn segment, then claims a
free slot in the listener's control segment under an ``flock`` (the only
cross-process lock; the data path is lock-free SPSC) and rings the
listener's doorbell.
"""
from __future__ import annotations

import errno
import fcntl
import hashlib
import os
import selectors
import socket
import struct
import tempfile
import threading
import uuid
from collections import deque
from multiprocessing import resource_tracker, shared_memory
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..types import MercuryError, Ret, _Counter
from .base import (NAAddress, NACallback, NACap, NAMemHandle, NAOp, NAPlugin,
                   TIER_SM, UNEXPECTED_MSG_LIMIT)

CTL_MAGIC = 0x534D4354
CONN_MAGIC = 0x534D434E

_URI_MAX = 255
_URI_OFF = 8                       # u16 len + bytes
_SLOTS_OFF = _URI_OFF + 2 + _URI_MAX + 7
N_SLOTS = 64
SLOT_SZ = 4 + _URI_MAX + 1         # state u8, pad, len u16, name
_MEMDIR_OFF = _SLOTS_OFF + N_SLOTS * SLOT_SZ
MEMDIR_ENTRIES = 128
_ENT = struct.Struct("<BxxxxxxxQQQBxH")   # state, key, off, size, flags, nlen
ENT_SZ = _ENT.size + _URI_MAX + 1
CTL_SIZE = _MEMDIR_OFF + MEMDIR_ENTRIES * ENT_SZ

RING_HDR = 32                      # head u64, tail u64, waiting u8, pad
RING_CAP = 4 * 1024 * 1024
_CONN_RINGS_OFF = _URI_OFF + 2 + _URI_MAX + 7
CONN_SIZE = _CONN_RINGS_OFF + 2 * (RING_HDR + RING_CAP)

_FRAME = struct.Struct("<IBQ")     # total (kind+tag+payload), kind, tag
K_UNEXP = 1
K_EXP = 2

_U64 = struct.Struct("<Q")

# process-local instance registry: in-process RMA fast path + uri probing
_PROCESS: Dict[str, "SMPlugin"] = {}  #: guarded-by _PROCESS_LOCK
_PROCESS_LOCK = threading.Lock()


def _digest(uri: str) -> str:
    return hashlib.sha1(uri.encode()).hexdigest()[:16]


def _rundir() -> str:
    d = os.path.join(tempfile.gettempdir(), "mjrp-sm")
    os.makedirs(d, exist_ok=True)
    return d


def _close_seg(shm: shared_memory.SharedMemory, unlink: bool = False) -> None:
    try:
        shm.close()
    except BufferError:
        pass                    # user-held views (alloc_array) keep it mapped
    if unlink:
        try:
            shm.unlink()
        except FileNotFoundError:
            pass


_CREATED_HERE: set = set()          # segment names this process created


def _create(name: str, size: int) -> shared_memory.SharedMemory:
    shm = shared_memory.SharedMemory(name=name, create=True, size=size)
    _CREATED_HERE.add(shm.name)
    return shm


def _attach(name: str) -> shared_memory.SharedMemory:
    """Attach a segment without letting resource_tracker unlink it when
    *this* process exits (CPython registers on attach too — bpo-39959).
    Segments created by this very process keep their registration: the
    creator's unlink() is what balances it."""
    shm = shared_memory.SharedMemory(name=name)
    if shm.name not in _CREATED_HERE:
        try:
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
    return shm


def _put_str(mv: memoryview, off: int, s: str) -> None:
    b = s.encode()
    if len(b) > _URI_MAX:
        raise MercuryError(Ret.INVALID_ARG, f"uri too long: {s}")
    struct.pack_into("<H", mv, off, len(b))
    mv[off + 2:off + 2 + len(b)] = b


def _get_str(mv: memoryview, off: int) -> str:
    (n,) = struct.unpack_from("<H", mv, off)
    return bytes(mv[off + 2:off + 2 + n]).decode()


class SMAddress(NAAddress):
    def __init__(self, uri: str):
        self.uri = uri


class _Ring:
    """SPSC circular byte ring over a segment slice.  The producer owns
    ``head``, the consumer owns ``tail``; both are monotonically
    increasing u64s, so no modular ambiguity between full and empty."""

    __slots__ = ("mv", "base", "cap", "data")

    def __init__(self, mv: memoryview, base: int, cap: int = RING_CAP):
        self.mv = mv
        self.base = base
        self.cap = cap
        self.data = mv[base + RING_HDR:base + RING_HDR + cap]

    @property
    def head(self) -> int:
        return _U64.unpack_from(self.mv, self.base)[0]

    @head.setter
    def head(self, v: int) -> None:
        _U64.pack_into(self.mv, self.base, v)

    @property
    def tail(self) -> int:
        return _U64.unpack_from(self.mv, self.base + 8)[0]

    @tail.setter
    def tail(self, v: int) -> None:
        _U64.pack_into(self.mv, self.base + 8, v)

    @property
    def waiting(self) -> bool:
        return self.mv[self.base + 16] != 0

    @waiting.setter
    def waiting(self, v: bool) -> None:
        self.mv[self.base + 16] = 1 if v else 0

    def _copy_in(self, pos: int, data) -> None:
        pos %= self.cap
        first = min(len(data), self.cap - pos)
        self.data[pos:pos + first] = data[:first]
        if first < len(data):
            self.data[:len(data) - first] = data[first:]

    def _copy_out(self, pos: int, n: int) -> bytes:
        pos %= self.cap
        first = min(n, self.cap - pos)
        out = bytes(self.data[pos:pos + first])
        if first < n:
            out += bytes(self.data[:n - first])
        return out

    def try_write(self, frame: bytes) -> bool:
        head = self.head
        if self.cap - (head - self.tail) < len(frame):
            return False
        self._copy_in(head, frame)
        self.head = head + len(frame)      # publish after the data lands
        return True

    def try_read(self) -> Optional[Tuple[int, int, bytes]]:
        tail = self.tail
        if self.head - tail < _FRAME.size:
            return None
        total, kind, tag = _FRAME.unpack(self._copy_out(tail, _FRAME.size))
        payload = self._copy_out(tail + _FRAME.size, total - 9)
        self.tail = tail + _FRAME.size + total - 9
        return kind, tag, payload

    def release(self) -> None:
        self.data.release()


class _SMConn:
    __slots__ = ("shm", "tx", "rx", "peer_uri", "bell_fd", "backlog",
                 "owner", "closed")

    def __init__(self, shm: shared_memory.SharedMemory, tx: _Ring, rx: _Ring,
                 peer_uri: str, bell_fd: int, owner: bool):
        self.shm = shm
        self.tx = tx
        self.rx = rx
        self.peer_uri = peer_uri
        self.bell_fd = bell_fd
        self.backlog: Deque[bytes] = deque()
        self.owner = owner
        self.closed = False


class SMPlugin(NAPlugin):
    name = "sm"
    caps = NACap.NATIVE_RMA | NACap.ZERO_COPY | NACap.SAME_HOST
    tier = TIER_SM
    max_unexpected_size = UNEXPECTED_MSG_LIMIT
    max_expected_size = RING_CAP - 64

    def __init__(self, uri: Optional[str] = None):
        super().__init__()
        if uri is None:
            uri = f"sm://p{os.getpid()}-{uuid.uuid4().hex[:8]}"
        elif not uri.startswith("sm://"):
            uri = "sm://" + uri
        self._uri = uri
        self._digest = _digest(uri)
        self._lock = threading.Lock()
        self._pending: Deque = deque()  #: guarded-by _lock

        # control segment + doorbell, all inside the connect lock: stale
        # takeover must not race a second process claiming the same uri,
        # and the segment/FIFO must be fully initialized before anyone
        # probing under the lock can see them (a half-written ctl would
        # read as stale or corrupt).
        self._bell_path = os.path.join(_rundir(), self._digest + ".bell")
        lfd = os.open(os.path.join(_rundir(), self._digest + ".lock"),
                      os.O_CREAT | os.O_RDWR, 0o600)
        try:
            fcntl.flock(lfd, fcntl.LOCK_EX)
            try:
                self._ctl = _create(f"mjrp-ct-{self._digest}", CTL_SIZE)
            except FileExistsError:
                if not self._uri_is_stale():
                    raise MercuryError(Ret.INVALID_ARG, f"sm uri in use: {uri}")
                # crashed predecessor: reclaim its name
                try:
                    old = shared_memory.SharedMemory(
                        name=f"mjrp-ct-{self._digest}")
                    old.close()
                    old.unlink()
                except FileNotFoundError:
                    pass
                try:
                    os.unlink(self._bell_path)
                except OSError:
                    pass
                self._ctl = _create(f"mjrp-ct-{self._digest}", CTL_SIZE)
            mv = self._ctl.buf
            mv[:CTL_SIZE] = b"\x00" * CTL_SIZE
            struct.pack_into("<IB", mv, 0, CTL_MAGIC, 1)
            _put_str(mv, _URI_OFF, uri)
            try:
                os.mkfifo(self._bell_path)
            except FileExistsError:
                pass
            # O_RDWR (not O_RDONLY): with a read-only fd the FIFO latches
            # EOF once the last writer closes and the selector reports it
            # readable forever — a 100% CPU busy-spin.  Keeping our own
            # writer open means reads just return EAGAIN.  (Liveness
            # probing still works: this fd is also the FIFO's reader, and
            # it closes when this process dies.)
            self._bell_r = os.open(self._bell_path,
                                   os.O_RDWR | os.O_NONBLOCK)
        finally:
            fcntl.flock(lfd, fcntl.LOCK_UN)
            os.close(lfd)
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_pending = False      # suppress redundant wake syscalls
        self._scan_slots = True         # scan peer slots on doorbell only
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._bell_r, selectors.EVENT_READ, "bell")
        self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")

        # messaging state. Unlike tcp, the *send* path needs no selector,
        # so senders write rings directly from their own thread under
        # _tx_lock (one fewer handoff per hop — the shm latency win);
        # receive-side state stays owned by the progress thread.
        self._tx_lock = threading.Lock()
        self._conns: Dict[str, _SMConn] = {}  #: guarded-by _tx_lock
        # doorbell-coalescing counters (under _tx_lock on the send path):
        # bells/frames ≪ 1 under burst is the win bench_core asserts
        self.stat_frames = 0  #: guarded-by _tx_lock
        self.stat_bells = 0  #: guarded-by _tx_lock
        self._recv_unexpected: Deque[Tuple[NAOp, NACallback]] = deque()
        self._in_unexpected: Deque[Tuple[str, int, memoryview]] = deque()
        self._recv_expected: List[Tuple[NAOp, Optional[str], int, NACallback]] = []
        self._in_expected: Deque[Tuple[str, int, memoryview]] = deque()
        self._completions: Deque[Tuple[NAOp, NACallback, Tuple]] = deque()

        # RMA state (shared with caller threads → _lock)
        self._mem: Dict[int, Tuple[memoryview, bool, bool, Optional[int]]] = {}  #: guarded-by _lock
        self._allocs: List[Tuple[str, shared_memory.SharedMemory, int, int]] = []  #: guarded-by _lock
        self._peer_ctls: Dict[str, shared_memory.SharedMemory] = {}  #: guarded-by _lock
        self._finalized = False

        with _PROCESS_LOCK:
            _PROCESS[uri] = self

    def _uri_is_stale(self) -> bool:
        """True when the ctl segment's owner is gone: its doorbell FIFO has
        no reader (or no FIFO at all)."""
        path = os.path.join(_rundir(), self._digest + ".bell")
        with _PROCESS_LOCK:
            if self._uri in _PROCESS:       # alive in this very process
                return False
        try:
            fd = os.open(path, os.O_WRONLY | os.O_NONBLOCK)
        except FileNotFoundError:
            return True
        except OSError as e:
            return e.errno == errno.ENXIO   # no reader on the FIFO
        os.close(fd)
        return False

    # -- addressing ----------------------------------------------------------
    def addr_self(self) -> NAAddress:
        return SMAddress(self._uri)

    def addr_lookup(self, uri: str) -> NAAddress:
        if not uri.startswith("sm://"):
            raise MercuryError(Ret.INVALID_ARG, f"not an sm uri: {uri}")
        self._peer_ctl(uri)            # reachability probe (same host only)
        return SMAddress(uri)

    def _peer_ctl(self, uri: str) -> memoryview:
        if uri == self._uri:
            return self._ctl.buf
        with self._lock:
            shm = self._peer_ctls.get(uri)
        if shm is None:
            # attach outside the lock (filesystem work), then publish with a
            # double-check: the loser of a concurrent attach closes its copy
            try:
                shm = _attach(f"mjrp-ct-{_digest(uri)}")
            except FileNotFoundError:
                raise MercuryError(Ret.DISCONNECT, f"no sm listener at {uri}")
            if struct.unpack_from("<I", shm.buf, 0)[0] != CTL_MAGIC:
                shm.close()
                raise MercuryError(Ret.PROTOCOL_ERROR, f"bad sm segment: {uri}")
            with self._lock:
                winner = self._peer_ctls.setdefault(uri, shm)
            if winner is not shm:
                _close_seg(shm)
                shm = winner
        return shm.buf

    # -- cross-thread posting -------------------------------------------------
    def _post(self, fn) -> None:
        with self._lock:
            self._pending.append(fn)
        self.interrupt()

    def interrupt(self) -> None:
        if self._wake_pending:
            return                      # a byte is already in flight
        self._wake_pending = True
        try:
            self._wake_w.send(b"\x00")
        except OSError:
            pass

    def _ring_bell(self, fd: int) -> bool:
        """Ring a peer's doorbell; False means the peer is gone (its FIFO
        lost its reader — the EPIPE doubles as liveness detection)."""
        try:
            os.write(fd, b"\x00")
            return True
        except BlockingIOError:
            return True                 # full FIFO already wakes the peer
        except OSError as e:
            return e.errno != errno.EPIPE

    # -- connection management (any thread; guarded by _tx_lock) --------------
    def _open_peer_bell(self, peer_uri: str) -> int:
        path = os.path.join(_rundir(), _digest(peer_uri) + ".bell")
        try:
            return os.open(path, os.O_WRONLY | os.O_NONBLOCK)
        except OSError:
            raise MercuryError(Ret.DISCONNECT, f"no sm doorbell at {peer_uri}")

    def _connect_locked(self, uri: str) -> _SMConn:
        if self._finalized:
            raise MercuryError(Ret.DISCONNECT, "sm plugin finalized")
        conn = self._conns.get(uri)
        if conn and not conn.closed:
            return conn
        ctl = self._peer_ctl(uri)
        seg = _create(f"mjrp-cn-{uuid.uuid4().hex[:16]}", CONN_SIZE)
        conn = None
        try:
            mv = seg.buf
            mv[:_CONN_RINGS_OFF] = b"\x00" * _CONN_RINGS_OFF
            for base in (_CONN_RINGS_OFF,
                         _CONN_RINGS_OFF + RING_HDR + RING_CAP):
                mv[base:base + RING_HDR] = b"\x00" * RING_HDR
            struct.pack_into("<IB", mv, 0, CONN_MAGIC, 1)
            _put_str(mv, _URI_OFF, self._uri)
            bell_fd = self._open_peer_bell(uri)
            conn = _SMConn(seg,
                           tx=_Ring(mv, _CONN_RINGS_OFF),
                           rx=_Ring(mv, _CONN_RINGS_OFF + RING_HDR + RING_CAP),
                           peer_uri=uri, bell_fd=bell_fd, owner=True)
            # claim a peer slot under the connect lock (the only x-proc lock)
            lock_path = os.path.join(_rundir(), _digest(uri) + ".lock")
            lfd = os.open(lock_path, os.O_CREAT | os.O_RDWR, 0o600)
            try:
                fcntl.flock(lfd, fcntl.LOCK_EX)
                for i in range(N_SLOTS):
                    off = _SLOTS_OFF + i * SLOT_SZ
                    if ctl[off] == 0:
                        _put_str(ctl, off + 2, seg.name)
                        ctl[off] = 1   # publish after the name is written
                        break
                else:
                    raise MercuryError(Ret.NOMEM,
                                       f"sm peer slots full at {uri}")
            finally:
                fcntl.flock(lfd, fcntl.LOCK_UN)
                os.close(lfd)
        except BaseException:
            if conn is not None:
                conn.tx.release()
                conn.rx.release()
                try:
                    os.close(conn.bell_fd)
                except OSError:
                    pass
            _close_seg(seg, unlink=True)
            raise
        self._conns[uri] = conn
        if not self._ring_bell(bell_fd):
            self._drop_conn_locked(conn)
            raise MercuryError(Ret.DISCONNECT, f"sm peer {uri} is gone")
        return conn

    def _accept_new(self) -> None:
        """Scan our slot table for freshly posted connections."""
        mv = self._ctl.buf
        for i in range(N_SLOTS):
            off = _SLOTS_OFF + i * SLOT_SZ
            if mv[off] != 1:
                continue
            name = _get_str(mv, off + 2)
            mv[off] = 0        # announcement consumed: slot reusable
            try:
                seg = _attach(name)
            except FileNotFoundError:
                continue
            peer_uri = _get_str(seg.buf, _URI_OFF)
            try:
                bell_fd = self._open_peer_bell(peer_uri)
            except MercuryError:
                seg.close()
                continue
            conn = _SMConn(
                seg,
                tx=_Ring(seg.buf, _CONN_RINGS_OFF + RING_HDR + RING_CAP),
                rx=_Ring(seg.buf, _CONN_RINGS_OFF),
                peer_uri=peer_uri, bell_fd=bell_fd, owner=False)
            with self._tx_lock:
                self._conns.setdefault(peer_uri, conn)
                if self._conns[peer_uri] is not conn:
                    # simultaneous connect: keep both data paths alive by
                    # draining this one too, under an aliased key
                    self._conns[f"{peer_uri}#{i}"] = conn

    def _drop_conn_locked(self, conn: _SMConn) -> None:
        """Tear down a connection whose peer is gone (called under
        _tx_lock); also invalidates the cached peer ctl so the next
        connect re-resolves a (possibly restarted) listener."""
        if conn.closed:
            return
        conn.closed = True
        conn.backlog.clear()
        try:
            os.close(conn.bell_fd)
        except OSError:
            pass
        conn.tx.release()
        conn.rx.release()
        _close_seg(conn.shm, unlink=conn.owner)
        for k in [k for k, c in self._conns.items() if c is conn]:
            del self._conns[k]
        with self._lock:
            stale_ctl = self._peer_ctls.pop(conn.peer_uri, None)
        if stale_ctl is not None:
            _close_seg(stale_ctl)

    def _enqueue_frame_locked(self, conn: _SMConn, kind: int, tag: int,
                       payload: bytes) -> None:
        frame = _FRAME.pack(len(payload) + 9, kind, tag) + payload
        if len(frame) > conn.tx.cap - 1:
            raise MercuryError(Ret.MSGSIZE,
                               f"frame {len(frame)}B exceeds sm ring")
        # Doorbell coalescing: one FIFO byte per idle→busy transition,
        # not per frame.  Sampled BEFORE our write lands — if the ring
        # already holds unconsumed frames (or a backlog is draining), a
        # previous bell is still pending for the peer and another byte is
        # pure syscall overhead.  Under an N-frame burst this collapses N
        # writes into ~1.  Races where the peer drains the ring between
        # our sample and our write are bounded by the multiplexer's 5ms
        # progress slice (core/na/multi.py) — progress() always drains
        # every conn, bell byte or not.
        was_idle = not conn.backlog and conn.tx.head == conn.tx.tail
        self.stat_frames += 1
        if conn.backlog or not conn.tx.try_write(frame):
            conn.backlog.append(frame)
            conn.tx.waiting = True
            # ring full: always ring — the bell doubles as the liveness
            # probe (EPIPE ⇒ peer gone) and a stalled consumer must not
            # be left unprodded while we hold a growing backlog
            was_idle = True
        if was_idle:
            self.stat_bells += 1
            if not self._ring_bell(conn.bell_fd):
                self._drop_conn_locked(conn)
                raise MercuryError(Ret.DISCONNECT,
                                   f"sm peer {conn.peer_uri} is gone")

    def _flush_backlog_locked(self, conn: _SMConn) -> None:
        wrote = False
        while conn.backlog and conn.tx.try_write(conn.backlog[0]):
            conn.backlog.popleft()
            wrote = True
        if not conn.backlog:
            conn.tx.waiting = False
        if wrote:
            self.stat_bells += 1        # one bell per flush, not per frame
            if not self._ring_bell(conn.bell_fd):
                self._drop_conn_locked(conn)

    # -- messaging API ---------------------------------------------------------
    def _send(self, kind: str, wire_kind: int, dest, data, tag, cb,
              limit: int) -> NAOp:
        self._check_msg_size(data, limit, kind)
        op = self._new_op(f"send_{kind}")
        flat = b"".join(bytes(memoryview(d).cast("B")) for d in data) \
            if isinstance(data, tuple) else bytes(memoryview(data).cast("B"))

        # write the ring from the caller's thread: the shm send path needs
        # no selector, so the message lands before the peer's next wakeup
        try:
            with self._tx_lock:
                conn = self._connect_locked(dest.uri)
                self._enqueue_frame_locked(conn, wire_kind, tag, flat)
            ret = Ret.SUCCESS
        except MercuryError as e:
            ret = e.ret
        self._complete_later(op, cb, (ret,))
        return op

    def msg_send_unexpected(self, dest, data, tag, cb) -> NAOp:
        return self._send("unexpected", K_UNEXP, dest, data, tag, cb,
                          self.max_unexpected_size)

    def msg_send_expected(self, dest, data, tag, cb) -> NAOp:
        return self._send("expected", K_EXP, dest, data, tag, cb,
                          self.max_expected_size)

    def msg_recv_unexpected(self, cb) -> NAOp:
        op = self._new_op("recv_unexpected")
        self._post(lambda: self._recv_unexpected.append((op, cb)))
        return op

    def msg_recv_expected(self, source, tag, cb) -> NAOp:
        op = self._new_op("recv_expected")
        src = source.uri if source is not None else None
        self._post(lambda: self._recv_expected.append((op, src, tag, cb)))
        return op

    # -- RMA -------------------------------------------------------------------
    def alloc_array(self, shape, dtype=np.uint8) -> np.ndarray:
        """Allocate an ndarray in a shared-memory segment.  Registration of
        such arrays is visible to peers in *other* processes (memdir)."""
        dtype = np.dtype(dtype)
        nbytes = max(1, int(np.prod(shape)) * dtype.itemsize)
        seg = _create(f"mjrp-rm-{uuid.uuid4().hex[:16]}", nbytes)
        base_addr = np.frombuffer(seg.buf, np.uint8).__array_interface__["data"][0]
        with self._lock:
            self._allocs.append((seg.name, seg, base_addr, seg.size))
        return np.ndarray(shape, dtype=dtype, buffer=seg.buf)

    def alloc_msg_buffer(self, nbytes: int) -> np.ndarray:
        """Rendezvous payloads must live in shm so peers in other
        processes can pull them one-sidedly."""
        return self.alloc_array((max(1, nbytes),), np.uint8)

    def free_msg_buffer(self, arr: np.ndarray) -> None:
        backing = self._shm_backing(self.as_view(arr))
        if backing is None:
            return
        name = backing[0]
        with self._lock:
            for i, (n, seg, _base, _size) in enumerate(self._allocs):
                if n == name:
                    del self._allocs[i]
                    break
            else:
                return
        _close_seg(seg, unlink=True)

    def _shm_backing(self, view: memoryview) -> Optional[Tuple[str, int]]:
        if view.nbytes == 0:
            return None
        addr = np.frombuffer(view, np.uint8).__array_interface__["data"][0]
        with self._lock:
            for name, _seg, base, size in self._allocs:
                if base <= addr and addr + view.nbytes <= base + size:
                    return name, addr - base
        return None

    def mem_register(self, buf, read=True, write=True, key=None) -> NAMemHandle:
        view = self.as_view(buf)
        key = key if key is not None else self._mem_counter.next()
        backing = self._shm_backing(view)
        ent = None
        if backing is not None:
            seg_name, seg_off = backing
            mv = self._ctl.buf
            with self._lock:
                for i in range(MEMDIR_ENTRIES):
                    off = _MEMDIR_OFF + i * ENT_SZ
                    if mv[off] == 0:
                        flags = (1 if read else 0) | (2 if write else 0)
                        name_b = seg_name.encode()
                        _ENT.pack_into(mv, off, 0, key, seg_off, view.nbytes,
                                       flags, len(name_b))
                        mv[off + _ENT.size:off + _ENT.size + len(name_b)] = name_b
                        mv[off] = 1    # publish last
                        ent = i
                        break
                else:
                    # failing loudly beats a misleading cross-process
                    # PERMISSION error at the (remote) point of use
                    raise MercuryError(
                        Ret.NOMEM, "sm memdir full: too many concurrently "
                                   "registered shm-backed buffers")
        with self._lock:
            self._mem[key] = (view, read, write, ent)
        return NAMemHandle(key=key, size=view.nbytes, owner_uri=self._uri,
                           read_allowed=read, write_allowed=write,
                           local_buf=view)

    def mem_deregister(self, mh: NAMemHandle) -> None:
        with self._lock:
            entry = self._mem.pop(mh.key, None)
            if entry is not None and entry[3] is not None:
                self._ctl.buf[_MEMDIR_OFF + entry[3] * ENT_SZ] = 0

    def _remote_view(self, dest: NAAddress, remote: NAMemHandle,
                     want_write: bool):
        """Resolve the destination buffer for a one-sided op — without any
        involvement of the target's progress loop.  Returns ``(view, seg)``;
        ``seg`` is a per-op attachment the caller must release after the
        copy (None for in-process peers).  Attachments are deliberately not
        cached: rendezvous payload segments are one-shot, and caching them
        would pin every unlinked payload mapping until finalize."""
        with _PROCESS_LOCK:
            peer = _PROCESS.get(dest.uri)
        if peer is not None and not peer._finalized:
            with peer._lock:
                entry = peer._mem.get(remote.key)
            if entry is None:
                raise MercuryError(Ret.PERMISSION,
                                   f"mem key {remote.key} not registered at {dest.uri}")
            view, read, write, _ = entry
            if want_write and not write:
                raise MercuryError(Ret.PERMISSION, "remote handle is read-only")
            if not want_write and not read:
                raise MercuryError(Ret.PERMISSION, "remote handle is write-only")
            return view, None
        # cross-process: consult the owner's memdir
        ctl = self._peer_ctl(dest.uri)
        for i in range(MEMDIR_ENTRIES):
            off = _MEMDIR_OFF + i * ENT_SZ
            state, key, seg_off, size, flags, nlen = _ENT.unpack_from(ctl, off)
            if state != 1 or key != remote.key:
                continue
            if want_write and not flags & 2:
                raise MercuryError(Ret.PERMISSION, "remote handle is read-only")
            if not want_write and not flags & 1:
                raise MercuryError(Ret.PERMISSION, "remote handle is write-only")
            name = bytes(ctl[off + _ENT.size:off + _ENT.size + nlen]).decode()
            try:
                seg = _attach(name)
            except FileNotFoundError:
                raise MercuryError(Ret.DISCONNECT,
                                   f"sm RMA segment {name} vanished")
            return seg.buf[seg_off:seg_off + size], seg
        raise MercuryError(
            Ret.PERMISSION,
            f"mem key {remote.key} not in {dest.uri} memdir (cross-process "
            f"sm RMA needs shm-backed buffers; see SMPlugin.alloc_array)")

    def _rma(self, kind: str, local, local_off, dest, remote, remote_off,
             size, cb, want_write: bool) -> NAOp:
        op = self._new_op(kind)
        rview, seg = self._remote_view(dest, remote, want_write=want_write)
        try:
            if remote_off + size > rview.nbytes or \
                    local_off + size > local.local_buf.nbytes:
                raise MercuryError(Ret.INVALID_ARG, f"RMA {kind} out of bounds")
            if want_write:
                rview[remote_off:remote_off + size] = \
                    local.local_buf[local_off:local_off + size]
            else:
                local.local_buf[local_off:local_off + size] = \
                    rview[remote_off:remote_off + size]
        finally:
            if seg is not None:
                rview.release()
                _close_seg(seg)
        self._complete_later(op, cb, (Ret.SUCCESS,))
        return op

    def put(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        return self._rma("put", local, local_off, dest, remote, remote_off,
                         size, cb, want_write=True)

    def get(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        return self._rma("get", local, local_off, dest, remote, remote_off,
                         size, cb, want_write=False)

    def _complete_later(self, op: NAOp, cb: NACallback, args: Tuple) -> None:
        self._post(lambda: self._completions.append((op, cb, args)))

    # -- progress --------------------------------------------------------------
    def _match_queues(self) -> None:
        while self._in_unexpected and self._recv_unexpected:
            op, cb = self._recv_unexpected.popleft()
            if op.canceled:
                continue
            src, tag, data = self._in_unexpected.popleft()
            op.done = True
            self._completions.append((op, cb, (Ret.SUCCESS, SMAddress(src),
                                               tag, data)))
        if self._in_expected:
            remaining = deque()
            while self._in_expected:
                src, tag, data = self._in_expected.popleft()
                hit = None
                for i, (op, want_src, want_tag, cb) in enumerate(self._recv_expected):
                    if op.canceled:
                        continue
                    if want_tag == tag and (want_src is None or want_src == src):
                        hit = i
                        break
                if hit is None:
                    remaining.append((src, tag, data))
                else:
                    op, _, _, cb = self._recv_expected.pop(hit)
                    op.done = True
                    self._completions.append((op, cb, (Ret.SUCCESS, data)))
            self._in_expected = remaining
        self._recv_expected = [r for r in self._recv_expected
                               if not r[0].canceled]

    def _drain_conn(self, conn: _SMConn) -> None:
        consumed = False
        while True:
            frame = conn.rx.try_read()
            if frame is None:
                break
            consumed = True
            kind, tag, payload = frame
            if kind == K_UNEXP:
                self._in_unexpected.append((conn.peer_uri, tag,
                                            memoryview(payload)))
            elif kind == K_EXP:
                self._in_expected.append((conn.peer_uri, tag,
                                          memoryview(payload)))
        if consumed and conn.rx.waiting:
            conn.rx.waiting = False
            self._ring_bell(conn.bell_fd)   # peer has backlog; space freed
        with self._tx_lock:
            self._flush_backlog_locked(conn)

    def _run_pending(self) -> None:
        while True:
            with self._lock:
                if not self._pending:
                    return
                fn = self._pending.popleft()
            fn()

    def progress(self, timeout: float) -> bool:
        if self._finalized:
            return False
        self._run_pending()
        if self._completions or self._pending:
            timeout = 0
        events = self._sel.select(timeout if timeout > 0 else 0)
        for key, _mask in events:
            try:
                if key.data == "bell":
                    self._scan_slots = True
                    while os.read(self._bell_r, 4096):
                        pass
                else:
                    while self._wake_r.recv(4096):
                        pass
                    self._wake_pending = False
            except (BlockingIOError, InterruptedError, OSError):
                if key.data != "bell":
                    self._wake_pending = False
        self._run_pending()
        if self._scan_slots:
            self._scan_slots = False
            self._accept_new()
        with self._tx_lock:
            conns = list(self._conns.values())
        for conn in conns:
            if not conn.closed:
                self._drain_conn(conn)
        self._match_queues()

        fired = False
        while self._completions:
            op, cb, args = self._completions.popleft()
            if op.canceled:
                continue
            op.done = True
            fired = True
            cb(*args)
        return fired

    def finalize(self) -> None:
        if self._finalized:
            return
        self._finalized = True
        with _PROCESS_LOCK:
            _PROCESS.pop(self._uri, None)
        self.interrupt()
        with self._tx_lock:
            conns = list(self._conns.values())
            self._conns.clear()
        with self._lock:
            peer_ctls = list(self._peer_ctls.values())
            self._peer_ctls.clear()
            allocs = list(self._allocs)
            self._allocs.clear()
        for conn in conns:
            conn.closed = True
            try:
                os.close(conn.bell_fd)
            except OSError:
                pass
            conn.tx.release()
            conn.rx.release()
            _close_seg(conn.shm, unlink=conn.owner)
        for shm in peer_ctls:
            _close_seg(shm)
        for _name, seg, _base, _size in allocs:
            _close_seg(seg, unlink=True)
        try:
            self._sel.close()
        except Exception:
            pass
        for fd in (self._bell_r,):
            try:
                os.close(fd)
            except OSError:
                pass
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
        _close_seg(self._ctl, unlink=True)
        try:
            os.unlink(self._bell_path)
        except OSError:
            pass
