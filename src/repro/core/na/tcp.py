"""``tcp`` NA plugin — non-blocking sockets, real multi-process transport.

This is the DCN-side transport for host services on a TPU cluster. RMA is
emulated with request/response frames (exactly how Mercury's tcp providers
implement NA put/get when the fabric has no one-sided verbs): the API stays
one-sided — the *target of the transfer* never posts anything; its progress
loop serves registered memory.

Threading model: any thread may post operations; a single thread (usually
the Engine's progress thread) calls :meth:`progress`, which owns the
selector. Cross-thread posts are handed over via a queue + wakeup pipe.
"""
from __future__ import annotations

import errno
import selectors
import socket
import struct
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..types import MercuryError, Ret, _Counter
from .base import (NAAddress, NACallback, NACap, NAMemHandle, NAOp, NAPlugin,
                   TIER_NET, UNEXPECTED_MSG_LIMIT)

_U32 = struct.Struct("<I")
_FRAME_HDR = struct.Struct("<IB")  # total payload len (incl kind byte? no: after), kind

K_HELLO = 0
K_UNEXP = 1
K_EXP = 2
K_GET_REQ = 3
K_GET_RSP = 4
K_PUT = 5
K_PUT_ACK = 6

_TAG = struct.Struct("<Q")
_GET_REQ = struct.Struct("<QQQQ")      # token, key, off, len
_RMA_RSP = struct.Struct("<QB")        # token, ret
_PUT_HDR = struct.Struct("<QQQ")       # token, key, off

MAX_FRAME = 64 * 1024 * 1024


class TCPAddress(NAAddress):
    def __init__(self, uri: str):
        self.uri = uri


def _parse_uri(uri: str) -> Tuple[str, int]:
    if not uri.startswith("tcp://"):
        raise MercuryError(Ret.INVALID_ARG, f"not a tcp uri: {uri}")
    hostport = uri[len("tcp://"):]
    host, _, port = hostport.rpartition(":")
    return host, int(port)


class _Conn:
    __slots__ = ("sock", "peer_uri", "inbuf", "outbuf", "registered",
                 "closed", "said_hello")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.peer_uri: Optional[str] = None
        self.inbuf = bytearray()
        self.outbuf: Deque[memoryview] = deque()
        self.registered = False
        self.closed = False
        self.said_hello = False

    def queue(self, *chunks: bytes) -> None:
        for c in chunks:
            if c:
                self.outbuf.append(memoryview(c))


class TCPPlugin(NAPlugin):
    name = "tcp"
    caps = NACap.NONE                    # RMA is frame-emulated
    tier = TIER_NET
    max_unexpected_size = UNEXPECTED_MSG_LIMIT
    max_expected_size = MAX_FRAME - 4096  # response framing headroom

    def __init__(self, uri: Optional[str] = None, listen: bool = True):
        super().__init__()
        self._sel = selectors.DefaultSelector()
        self._lock = threading.Lock()
        self._pending: Deque = deque()        # cross-thread posted ops
        self._conns: Dict[str, _Conn] = {}    # peer_uri -> conn
        self._listener: Optional[socket.socket] = None
        self._anon_counter = _Counter()

        # wakeup pipe
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._sel.register(self._wake_r, selectors.EVENT_READ, ("wake", None))

        if listen:
            host, port = ("127.0.0.1", 0)
            if uri:
                host, port = _parse_uri(uri)
            ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            ls.bind((host, port))
            ls.listen(128)
            ls.setblocking(False)
            self._listener = ls
            self._uri = f"tcp://{ls.getsockname()[0]}:{ls.getsockname()[1]}"
            self._sel.register(ls, selectors.EVENT_READ, ("accept", None))
        else:
            self._uri = f"tcp-anon://{id(self):x}"

        # posted receives / queues (owned by progress thread)
        self._recv_unexpected: Deque[Tuple[NAOp, NACallback]] = deque()
        self._in_unexpected: Deque[Tuple[str, int, memoryview]] = deque()
        self._recv_expected: List[Tuple[NAOp, Optional[str], int, NACallback]] = []
        self._in_expected: Deque[Tuple[str, int, memoryview]] = deque()
        self._mem: Dict[int, Tuple[memoryview, bool, bool]] = {}  #: guarded-by _lock
        self._rma_pending: Dict[int, Tuple[NAOp, NACallback, NAMemHandle, int]] = {}
        self._rma_token = _Counter()
        self._completions: Deque[Tuple[NAOp, NACallback, Tuple]] = deque()
        self._finalized = False

    # -- addressing ----------------------------------------------------------
    def addr_self(self) -> NAAddress:
        return TCPAddress(self._uri)

    def addr_lookup(self, uri: str) -> NAAddress:
        if not (uri.startswith("tcp://") or uri.startswith("tcp-anon://")):
            raise MercuryError(Ret.INVALID_ARG, f"not a tcp uri: {uri}")
        return TCPAddress(uri)

    # -- cross-thread posting -------------------------------------------------
    def _post(self, fn) -> None:
        with self._lock:
            self._pending.append(fn)
        self.interrupt()

    def interrupt(self) -> None:
        try:
            self._wake_w.send(b"\x00")
        except OSError:
            pass

    # -- connection management (progress thread only) -------------------------
    def _connect(self, uri: str) -> _Conn:
        conn = self._conns.get(uri)
        if conn and not conn.closed:
            return conn
        if uri.startswith("tcp-anon://"):
            raise MercuryError(Ret.DISCONNECT, f"anonymous peer {uri} not connected")
        host, port = _parse_uri(uri)
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.setblocking(False)
        try:
            s.connect((host, port))
        except BlockingIOError:
            pass
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        conn = _Conn(s)
        conn.peer_uri = uri
        self._conns[uri] = conn
        self._sel.register(s, selectors.EVENT_READ | selectors.EVENT_WRITE,
                           ("conn", conn))
        conn.registered = True
        # first frame: HELLO with our uri so the peer can address us
        self._send_frame(conn, K_HELLO, self._uri.encode())
        return conn

    def _send_frame(self, conn: _Conn, kind: int, *parts: bytes) -> None:
        total = sum(len(p) for p in parts)
        if total + 1 > MAX_FRAME:
            raise MercuryError(Ret.INVALID_ARG, f"frame too large: {total}")
        conn.queue(_FRAME_HDR.pack(total + 1, kind), *parts)
        self._want_write(conn)

    def _want_write(self, conn: _Conn) -> None:
        if conn.registered and not conn.closed:
            self._sel.modify(conn.sock, selectors.EVENT_READ | selectors.EVENT_WRITE,
                             ("conn", conn))

    def _close_conn(self, conn: _Conn, ret: Ret = Ret.DISCONNECT) -> None:
        if conn.closed:
            return
        conn.closed = True
        try:
            if conn.registered:
                self._sel.unregister(conn.sock)
        except (KeyError, ValueError):
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        if conn.peer_uri and self._conns.get(conn.peer_uri) is conn:
            del self._conns[conn.peer_uri]
        # fail pending RMA ops routed to this peer
        dead = [t for t, (_op, _cb, _mh, _sz) in self._rma_pending.items()]
        for t in dead:
            op, cb, _mh, _sz = self._rma_pending[t]
            if op.user == conn.peer_uri:
                del self._rma_pending[t]
                self._completions.append((op, cb, (ret,)))
        # fail expected receives bound to this source
        still = []
        for op, src, tag, cb in self._recv_expected:
            if src is not None and src == conn.peer_uri:
                self._completions.append((op, cb, (ret, memoryview(b""))))
            else:
                still.append((op, src, tag, cb))
        self._recv_expected = still

    # -- messaging API ---------------------------------------------------------
    def msg_send_unexpected(self, dest, data, tag, cb) -> NAOp:
        self._check_msg_size(data, self.max_unexpected_size, "unexpected")
        op = self._new_op("send_unexpected")
        if not isinstance(data, tuple):
            data = bytes(data)

        def do():
            try:
                conn = self._connect(dest.uri)
                parts = data if isinstance(data, tuple) else (data,)
                self._send_frame(conn, K_UNEXP, _TAG.pack(tag), *parts)
                self._completions.append((op, cb, (Ret.SUCCESS,)))
            except MercuryError as e:
                self._completions.append((op, cb, (e.ret,)))

        self._post(do)
        return op

    def msg_recv_unexpected(self, cb) -> NAOp:
        op = self._new_op("recv_unexpected")
        self._post(lambda: self._recv_unexpected.append((op, cb)))
        return op

    def msg_send_expected(self, dest, data, tag, cb) -> NAOp:
        self._check_msg_size(data, self.max_expected_size, "expected")
        op = self._new_op("send_expected")
        if not isinstance(data, tuple):
            data = bytes(data)

        def do():
            try:
                conn = self._connect(dest.uri)
                parts = data if isinstance(data, tuple) else (data,)
                self._send_frame(conn, K_EXP, _TAG.pack(tag), *parts)
                self._completions.append((op, cb, (Ret.SUCCESS,)))
            except MercuryError as e:
                self._completions.append((op, cb, (e.ret,)))

        self._post(do)
        return op

    def msg_recv_expected(self, source, tag, cb) -> NAOp:
        op = self._new_op("recv_expected")
        src = source.uri if source is not None else None
        self._post(lambda: self._recv_expected.append((op, src, tag, cb)))
        return op

    # -- RMA ---------------------------------------------------------------------
    def mem_register(self, buf, read=True, write=True, key=None) -> NAMemHandle:
        view = self.as_view(buf)
        key = key if key is not None else self._mem_counter.next()
        with self._lock:
            self._mem[key] = (view, read, write)
        return NAMemHandle(key=key, size=view.nbytes, owner_uri=self._uri,
                           read_allowed=read, write_allowed=write, local_buf=view)

    def mem_deregister(self, mh: NAMemHandle) -> None:
        with self._lock:
            self._mem.pop(mh.key, None)

    def get(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        op = self._new_op("get")
        op.user = dest.uri

        def do():
            try:
                conn = self._connect(dest.uri)
                token = self._rma_token.next()
                self._rma_pending[token] = (op, cb, local, local_off)
                self._send_frame(conn, K_GET_REQ,
                                 _GET_REQ.pack(token, remote.key, remote_off, size))
            except MercuryError as e:
                self._completions.append((op, cb, (e.ret,)))

        self._post(do)
        return op

    def put(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        op = self._new_op("put")
        op.user = dest.uri
        payload = bytes(local.local_buf[local_off:local_off + size])

        def do():
            try:
                conn = self._connect(dest.uri)
                token = self._rma_token.next()
                self._rma_pending[token] = (op, cb, local, local_off)
                self._send_frame(conn, K_PUT,
                                 _PUT_HDR.pack(token, remote.key, remote_off), payload)
            except MercuryError as e:
                self._completions.append((op, cb, (e.ret,)))

        self._post(do)
        return op

    # -- frame handling (progress thread) -----------------------------------------
    def _on_frame(self, conn: _Conn, kind: int, payload: memoryview) -> None:
        if kind == K_HELLO:
            uri = bytes(payload).decode()
            conn.peer_uri = uri
            self._conns[uri] = conn
            return
        src = conn.peer_uri or f"tcp-anon://{self._anon_counter.next():x}"
        if kind == K_UNEXP:
            tag = _TAG.unpack_from(payload)[0]
            self._in_unexpected.append((src, tag, payload[_TAG.size:]))
        elif kind == K_EXP:
            tag = _TAG.unpack_from(payload)[0]
            self._in_expected.append((src, tag, payload[_TAG.size:]))
        elif kind == K_GET_REQ:
            token, key, off, ln = _GET_REQ.unpack_from(payload)
            with self._lock:
                entry = self._mem.get(key)
            if entry is None or not entry[1] or off + ln > entry[0].nbytes:
                self._send_frame(conn, K_GET_RSP, _RMA_RSP.pack(token, int(Ret.PERMISSION)))
            else:
                data = entry[0][off:off + ln]     # zero-copy: registered
                self._send_frame(conn, K_GET_RSP, _RMA_RSP.pack(token, int(Ret.SUCCESS)), data)
        elif kind == K_GET_RSP:
            token, ret = _RMA_RSP.unpack_from(payload)
            pend = self._rma_pending.pop(token, None)
            if pend is None:
                return
            op, cb, local, local_off = pend
            data = payload[_RMA_RSP.size:]
            if ret == Ret.SUCCESS:
                local.local_buf[local_off:local_off + len(data)] = data
            self._completions.append((op, cb, (Ret(ret),)))
        elif kind == K_PUT:
            token, key, off = _PUT_HDR.unpack_from(payload)
            data = payload[_PUT_HDR.size:]
            with self._lock:
                entry = self._mem.get(key)
            if entry is None or not entry[2] or off + len(data) > entry[0].nbytes:
                self._send_frame(conn, K_PUT_ACK, _RMA_RSP.pack(token, int(Ret.PERMISSION)))
            else:
                entry[0][off:off + len(data)] = data
                self._send_frame(conn, K_PUT_ACK, _RMA_RSP.pack(token, int(Ret.SUCCESS)))
        elif kind == K_PUT_ACK:
            token, ret = _RMA_RSP.unpack_from(payload)
            pend = self._rma_pending.pop(token, None)
            if pend is None:
                return
            op, cb, _local, _off = pend
            self._completions.append((op, cb, (Ret(ret),)))

    def _read_conn(self, conn: _Conn) -> None:
        try:
            while True:
                chunk = conn.sock.recv(1 << 18)
                if not chunk:
                    self._close_conn(conn)
                    return
                conn.inbuf += chunk
                if len(chunk) < (1 << 18):
                    break
        except (BlockingIOError, InterruptedError):
            pass
        except OSError:
            self._close_conn(conn)
            return
        # parse complete frames
        buf = conn.inbuf
        pos = 0
        n = len(buf)
        while n - pos >= _FRAME_HDR.size:
            total, kind = _FRAME_HDR.unpack_from(buf, pos)
            if total > MAX_FRAME:
                self._close_conn(conn, Ret.PROTOCOL_ERROR)
                return
            if n - pos - _U32.size < total:
                break
            start = pos + _FRAME_HDR.size
            end = pos + _U32.size + total
            self._on_frame(conn, kind, memoryview(bytes(buf[start:end])))
            pos = end
        if pos:
            del conn.inbuf[:pos]

    def _write_conn(self, conn: _Conn) -> None:
        try:
            while conn.outbuf:
                sent = conn.sock.send(conn.outbuf[0])
                if sent < len(conn.outbuf[0]):
                    conn.outbuf[0] = conn.outbuf[0][sent:]
                    return
                conn.outbuf.popleft()
        except (BlockingIOError, InterruptedError):
            return
        except OSError as e:
            if e.errno == errno.EINPROGRESS:
                return
            self._close_conn(conn)
            return
        if not conn.outbuf and conn.registered and not conn.closed:
            self._sel.modify(conn.sock, selectors.EVENT_READ, ("conn", conn))

    def _match_queues(self) -> None:
        while self._in_unexpected and self._recv_unexpected:
            op, cb = self._recv_unexpected.popleft()
            if op.canceled:
                continue
            src, tag, data = self._in_unexpected.popleft()
            op.done = True
            self._completions.append((op, cb, (Ret.SUCCESS, TCPAddress(src), tag, data)))
        if self._in_expected:
            remaining = deque()
            while self._in_expected:
                src, tag, data = self._in_expected.popleft()
                hit = None
                for i, (op, want_src, want_tag, cb) in enumerate(self._recv_expected):
                    if op.canceled:
                        continue
                    if want_tag == tag and (want_src is None or want_src == src):
                        hit = i
                        break
                if hit is None:
                    remaining.append((src, tag, data))
                else:
                    op, _, _, cb = self._recv_expected.pop(hit)
                    op.done = True
                    self._completions.append((op, cb, (Ret.SUCCESS, data)))
            self._in_expected = remaining
        self._recv_expected = [r for r in self._recv_expected if not r[0].canceled]

    def progress(self, timeout: float) -> bool:
        if self._finalized:
            return False
        # run cross-thread posted ops
        while True:
            with self._lock:
                if not self._pending:
                    break
                fn = self._pending.popleft()
            fn()

        events = self._sel.select(timeout if timeout > 0 else 0)
        for key, mask in events:
            what, obj = key.data
            if what == "wake":
                try:
                    while self._wake_r.recv(4096):
                        pass
                except (BlockingIOError, InterruptedError):
                    pass
            elif what == "accept":
                try:
                    while True:
                        s, _ = self._listener.accept()
                        s.setblocking(False)
                        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                        conn = _Conn(s)
                        self._sel.register(s, selectors.EVENT_READ, ("conn", conn))
                        conn.registered = True
                        self._send_frame(conn, K_HELLO, self._uri.encode())
                except (BlockingIOError, InterruptedError):
                    pass
            elif what == "conn":
                if mask & selectors.EVENT_WRITE:
                    self._write_conn(obj)
                if mask & selectors.EVENT_READ and not obj.closed:
                    self._read_conn(obj)

        # re-run posts that arrived during select
        while True:
            with self._lock:
                if not self._pending:
                    break
                fn = self._pending.popleft()
            fn()

        self._match_queues()

        fired = False
        while self._completions:
            op, cb, args = self._completions.popleft()
            if op.canceled:
                continue
            op.done = True
            fired = True
            cb(*args)
        return fired

    def finalize(self) -> None:
        self._finalized = True
        self.interrupt()
        for conn in list(self._conns.values()):
            self._close_conn(conn)
        for sock in (self._listener, self._wake_r, self._wake_w):
            try:
                if sock:
                    sock.close()
            except OSError:
                pass
        try:
            self._sel.close()
        except Exception:
            pass
