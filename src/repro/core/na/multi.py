"""Multi-transport NA plugin — locality-tiered address resolution.

A service that listens on several transports advertises an *address set*
(semicolon-joined URIs, cheapest tier first):

    self://svc1;sm://svc1;tcp://10.0.0.3:40125

``addr_lookup`` resolves an address set to the cheapest transport that can
actually reach the target (self > sm > tcp): ``self`` probes the
in-process registry, ``sm`` probes segment attachability (same host), and
``tcp`` always matches syntactically.  Every other operation routes by the
scheme of the (already resolved) concrete address, so upper layers —
HGClass, the bulk layer, services — stay completely transport-blind.

Memory registration registers the buffer with *every* transport under one
shared key, so a bulk descriptor minted here is valid no matter which tier
each peer resolves (see DESIGN.md §5).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from ..types import MercuryError, Ret
from .base import (NAAddress, NACallback, NACap, NAMemHandle, NAOp, NAPlugin,
                   SCHEME_TIERS)


def parse_addr_set(uri: str) -> List[str]:
    return [u for u in (p.strip() for p in uri.split(";")) if u]


def scheme_of(uri: str) -> str:
    return uri.split("://", 1)[0] if "://" in uri else uri


class MultiAddress(NAAddress):
    def __init__(self, uri: str):
        self.uri = uri


class MultiPlugin(NAPlugin):
    name = "multi"

    def __init__(self, plugins: Sequence[NAPlugin]):
        super().__init__()
        if not plugins:
            raise MercuryError(Ret.INVALID_ARG, "multi needs >= 1 plugin")
        self._plugins = sorted(plugins, key=lambda p: p.tier)
        self._by_scheme: Dict[str, NAPlugin] = {}
        for p in self._plugins:
            if p.name in self._by_scheme:
                raise MercuryError(Ret.INVALID_ARG,
                                   f"duplicate transport: {p.name}")
            self._by_scheme[p.name] = p
        self._by_scheme.setdefault("tcp-anon", self._by_scheme.get("tcp"))
        # conservative limits: a message must fit whichever tier resolves
        self.max_unexpected_size = min(p.max_unexpected_size
                                       for p in self._plugins)
        self.max_expected_size = min(p.max_expected_size
                                     for p in self._plugins)
        # unexpected-recv pump: one persistent pre-posted recv per transport
        # feeds a queue of logical recv ops (posting one recv per transport
        # per logical op would grow unboundedly under HGClass's repost loop)
        self._uq_lock = threading.Lock()
        self._uq: Deque[Tuple[NAOp, NACallback]] = deque()  #: guarded-by _uq_lock
        self._ustash: Deque[Tuple] = deque()  #: guarded-by _uq_lock
        self._pumps_armed = False  #: guarded-by _uq_lock

    def _route(self, addr: NAAddress) -> NAPlugin:
        p = self._by_scheme.get(scheme_of(addr.uri))
        if p is None:
            raise MercuryError(Ret.INVALID_ARG,
                               f"no transport for {addr.uri}")
        return p

    def caps_for(self, addr: NAAddress) -> NACap:
        return self._route(addr).caps

    def alloc_msg_buffer(self, nbytes: int):
        for p in self._plugins:
            buf = p.alloc_msg_buffer(nbytes)
            if buf is not None:
                return buf
        return None

    def free_msg_buffer(self, arr) -> None:
        for p in self._plugins:
            p.free_msg_buffer(arr)

    # -- addressing ----------------------------------------------------------
    def addr_self(self) -> NAAddress:
        return MultiAddress(";".join(p.addr_self().uri
                                     for p in self._plugins))

    def local_uris(self) -> List[str]:
        return [u for p in self._plugins for u in p.local_uris()]

    def addr_lookup(self, uri: str) -> NAAddress:
        cands = sorted(parse_addr_set(uri),
                       key=lambda u: SCHEME_TIERS.get(scheme_of(u), 99))
        last: Optional[MercuryError] = None
        for cand in cands:
            p = self._by_scheme.get(scheme_of(cand))
            if p is None:
                continue
            try:
                return p.addr_lookup(cand)
            except MercuryError as e:
                last = e
        raise last or MercuryError(Ret.NOENTRY,
                                   f"no reachable transport in {uri!r}")

    # -- two-sided messaging -------------------------------------------------
    def msg_send_unexpected(self, dest, data, tag, cb) -> NAOp:
        return self._route(dest).msg_send_unexpected(dest, data, tag, cb)

    def msg_send_expected(self, dest, data, tag, cb) -> NAOp:
        return self._route(dest).msg_send_expected(dest, data, tag, cb)

    def _arm_pump(self, p: NAPlugin) -> None:
        p.msg_recv_unexpected(
            lambda ret, src, tag, data, _p=p: self._on_unexp(_p, ret, src,
                                                             tag, data))

    def _on_unexp(self, p: NAPlugin, ret, src, tag, data) -> None:
        self._arm_pump(p)                  # keep the pipeline full
        with self._uq_lock:
            while self._uq:
                op, cb = self._uq.popleft()
                if op.canceled:
                    continue
                op.done = True
                break
            else:
                self._ustash.append((ret, src, tag, data))
                return
        cb(ret, src, tag, data)

    def _drain_stash(self) -> bool:
        fired = False
        while True:
            with self._uq_lock:
                if not self._ustash or not self._uq:
                    return fired
                msg = self._ustash.popleft()
                op, cb = self._uq.popleft()
                if op.canceled:
                    self._ustash.appendleft(msg)
                    continue
                op.done = True
            cb(*msg)
            fired = True

    def msg_recv_unexpected(self, cb) -> NAOp:
        op = self._new_op("recv_unexpected")
        with self._uq_lock:
            self._uq.append((op, cb))
            if not self._pumps_armed:
                self._pumps_armed = True
                for p in self._plugins:
                    self._arm_pump(p)
        self.interrupt()
        return op

    def msg_recv_expected(self, source, tag, cb) -> NAOp:
        if source is None:
            raise MercuryError(Ret.INVALID_ARG,
                               "multi-transport expected recv needs a source")
        return self._route(source).msg_recv_expected(source, tag, cb)

    # -- RMA -----------------------------------------------------------------
    def mem_register(self, buf, read=True, write=True, key=None) -> NAMemHandle:
        key = key if key is not None else self._mem_counter.next()
        sub: Dict[str, NAMemHandle] = {}
        try:
            for p in self._plugins:
                sub[p.name] = p.mem_register(buf, read=read, write=write,
                                             key=key)
        except MercuryError:
            for name, mh in sub.items():   # roll back partial registration
                self._by_scheme[name].mem_deregister(mh)
            raise
        first = sub[self._plugins[0].name]
        return NAMemHandle(key=key, size=first.size,
                           owner_uri=self.addr_self().uri,
                           read_allowed=read, write_allowed=write,
                           local_buf=first.local_buf, sub=sub)

    def mem_deregister(self, mh: NAMemHandle) -> None:
        for p in self._plugins:
            p.mem_deregister(mh)

    @staticmethod
    def _local_for(local: NAMemHandle, p: NAPlugin) -> NAMemHandle:
        return local.sub[p.name] if local.sub else local

    def put(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        p = self._route(dest)
        return p.put(self._local_for(local, p), local_off, dest, remote,
                     remote_off, size, cb)

    def get(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        p = self._route(dest)
        return p.get(self._local_for(local, p), local_off, dest, remote,
                     remote_off, size, cb)

    # -- progress ------------------------------------------------------------
    def progress(self, timeout: float) -> bool:
        deadline = time.monotonic() + timeout
        fired = self._drain_stash()
        for p in self._plugins:
            fired |= p.progress(0.0)
        fired |= self._drain_stash()
        if fired or timeout <= 0:
            return fired
        while True:
            for p in self._plugins:
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return False
                if p.progress(min(0.005, rem)) | self._drain_stash():
                    return True

    def interrupt(self) -> None:
        for p in self._plugins:
            p.interrupt()

    def finalize(self) -> None:
        for p in self._plugins:
            p.finalize()
