"""NA — the Network Abstraction layer (paper contribution C1).

Mercury's NA exposes *only* the minimal functionality an RPC layer needs,
which is what makes new fabric plugins cheap to write:

  * connectionless addressing  (``addr_lookup`` / ``addr_self``)
  * two-sided *unexpected* messages (small, unsolicited — RPC requests)
  * two-sided *expected* messages (pre-posted, tag-matched — responses)
  * one-sided RMA ``put``/``get`` against *registered memory* (bulk data)
  * a single ``progress`` entry point and per-op cancellation

Plugins implemented here:
  * ``self``  — in-process loopback (tests, benchmarks, co-located services)
  * ``sm``    — shared-memory rings + one-sided RMA over
                ``multiprocessing.shared_memory`` (same-host services)
  * ``tcp``   — real non-blocking sockets; RMA emulated with
                request/response chunks exactly like Mercury's tcp provider
On a real TPU cluster the host-side DCN uses ``tcp``; on-mesh (ICI) data
movement is compiled into XLA programs and is *not* routed through NA
(see DESIGN.md §2).
"""
from __future__ import annotations

import abc
import enum
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..types import MercuryError, Ret, _Counter

# NA-level callbacks: cb(ret: Ret, **op specific kwargs)
NACallback = Callable[..., None]

UNEXPECTED_MSG_LIMIT = 64 * 1024   # eager limit for unexpected messages
EXPECTED_MSG_LIMIT = 16 * 1024 * 1024


class NACap(enum.IntFlag):
    """Capability flags a plugin advertises (checked by upper layers)."""

    NONE = 0
    NATIVE_RMA = 1       # put/get is one-sided for real: no target-side
                         # progress, no request/response emulation
    ZERO_COPY = 2        # put/get is a single direct copy into the
                         # destination buffer (no framing/staging copies)
    SAME_HOST = 4        # transport only reaches peers on this host
    SAME_PROCESS = 8     # transport only reaches peers in this process


# Locality tiers — lower is cheaper; used by multi-transport resolution.
TIER_SELF = 0    # same process
TIER_SM = 1      # same host (shared memory)
TIER_NET = 2     # network

SCHEME_TIERS = {"self": TIER_SELF, "sm": TIER_SM,
                "tcp": TIER_NET, "tcp-anon": TIER_NET}


class NAAddress(abc.ABC):
    """Opaque address. Plugins subclass; must be hashable and must expose a
    reconnectable ``uri`` (used when serializing bulk descriptors)."""

    uri: str

    def __hash__(self):
        return hash(self.uri)

    def __eq__(self, other):
        return isinstance(other, NAAddress) and other.uri == self.uri

    def __repr__(self):
        return f"<addr {self.uri}>"


@dataclass
class NAMemHandle:
    """Registered-memory handle.

    ``key`` is meaningful to the *owning* plugin instance; remote peers
    refer to the memory by ``(uri, key)``. ``local_buf`` is only populated
    on the owning side.
    """

    key: int
    size: int
    owner_uri: str
    read_allowed: bool = True
    write_allowed: bool = True
    local_buf: Optional[memoryview] = None  # not serialized
    sub: Optional[Dict[str, "NAMemHandle"]] = None  # multi-transport aliases


class NAOp:
    """Handle for an in-flight NA operation (cancelable)."""

    __slots__ = ("op_id", "kind", "canceled", "done", "user")

    def __init__(self, op_id: int, kind: str):
        self.op_id = op_id
        self.kind = kind
        self.canceled = False
        self.done = False
        self.user: Any = None

    def __repr__(self):
        st = "done" if self.done else ("canceled" if self.canceled else "pending")
        return f"<NAOp {self.kind} #{self.op_id} {st}>"


class NAPlugin(abc.ABC):
    """Minimal transport plugin interface (mirrors na_class_t ops)."""

    name: str = "abstract"
    caps: NACap = NACap.NONE
    tier: int = TIER_NET
    # eager-message limits (see DESIGN.md §3): senders exceeding these get
    # Ret.MSGSIZE; the RPC layer switches to rendezvous before hitting them.
    max_unexpected_size: int = UNEXPECTED_MSG_LIMIT
    max_expected_size: int = EXPECTED_MSG_LIMIT

    def __init__(self):
        self._op_counter = _Counter()
        self._mem_counter = _Counter()

    def caps_for(self, addr: "NAAddress") -> NACap:
        """Capabilities in effect when talking to ``addr`` (multi-transport
        plugins route this per destination)."""
        return self.caps

    def local_uris(self) -> List[str]:
        """URIs under which peers *in this process* reach this plugin
        with SAME_PROCESS semantics (the ``self`` tier).  The RPC layer
        uses these to register for serialization-free local dispatch
        (DESIGN.md §9); transports that cross a process boundary return
        the default empty list."""
        return []

    # -- staging buffers ------------------------------------------------------
    def alloc_msg_buffer(self, nbytes: int) -> Optional[np.ndarray]:
        """Optional transport-preferred staging memory for rendezvous
        payloads.  Plugins whose RMA needs special memory (sm: shm-backed
        segments reachable from other processes) return an array here;
        ``None`` means plain heap memory works (self, tcp)."""
        return None

    def free_msg_buffer(self, arr: np.ndarray) -> None:
        """Release a buffer from :meth:`alloc_msg_buffer` (no-op for
        buffers this plugin does not own)."""

    # -- addressing --------------------------------------------------------
    @abc.abstractmethod
    def addr_self(self) -> NAAddress: ...

    @abc.abstractmethod
    def addr_lookup(self, uri: str) -> NAAddress: ...

    # -- two-sided messaging ------------------------------------------------
    @abc.abstractmethod
    def msg_send_unexpected(self, dest: NAAddress, data, tag: int,
                            cb: NACallback) -> NAOp:
        """Send a small unsolicited message. ``data`` may be bytes or a
        tuple of buffers (vectored send — avoids payload concatenation on
        plugins with scatter/gather framing). cb(ret)."""

    @abc.abstractmethod
    def msg_recv_unexpected(self, cb: NACallback) -> NAOp:
        """Post a receive for *any* unexpected message.
        cb(ret, source: NAAddress, tag: int, data: memoryview)."""

    @abc.abstractmethod
    def msg_send_expected(self, dest: NAAddress, data, tag: int,
                          cb: NACallback) -> NAOp:
        """Send a tag-matched message (data: bytes or buffer tuple). cb(ret)."""

    @abc.abstractmethod
    def msg_recv_expected(self, source: Optional[NAAddress], tag: int,
                          cb: NACallback) -> NAOp:
        """Post a tag-matched receive. cb(ret, data: memoryview)."""

    # -- one-sided RMA -------------------------------------------------------
    @abc.abstractmethod
    def mem_register(self, buf: memoryview | np.ndarray,
                     read: bool = True, write: bool = True,
                     key: Optional[int] = None) -> NAMemHandle:
        """Register memory for one-sided access.  ``key`` lets a wrapping
        multi-transport plugin assign one key valid across transports."""

    @abc.abstractmethod
    def mem_deregister(self, mh: NAMemHandle) -> None: ...

    @abc.abstractmethod
    def put(self, local: NAMemHandle, local_off: int, dest: NAAddress,
            remote: NAMemHandle, remote_off: int, size: int,
            cb: NACallback) -> NAOp:
        """One-sided write local[off:off+size] -> remote[off:off+size]. cb(ret)."""

    @abc.abstractmethod
    def get(self, local: NAMemHandle, local_off: int, dest: NAAddress,
            remote: NAMemHandle, remote_off: int, size: int,
            cb: NACallback) -> NAOp:
        """One-sided read remote -> local. cb(ret)."""

    # -- progress ------------------------------------------------------------
    @abc.abstractmethod
    def progress(self, timeout: float) -> bool:
        """Drive the transport for up to ``timeout`` seconds. Returns True if
        any completion fired (callbacks run inside this call)."""

    @abc.abstractmethod
    def interrupt(self) -> None:
        """Wake a blocked progress() from another thread."""

    def cancel(self, op: NAOp) -> None:
        op.canceled = True

    def finalize(self) -> None:
        pass

    # -- helpers -------------------------------------------------------------
    def _new_op(self, kind: str) -> NAOp:
        return NAOp(self._op_counter.next(), kind)

    def _check_msg_size(self, data, limit: int, kind: str) -> int:
        """Enforce an eager-message limit; returns the flattened length."""
        if isinstance(data, tuple):
            n = sum(len(memoryview(d).cast("B")) for d in data)
        else:
            n = len(memoryview(data).cast("B"))
        if n > limit:
            raise MercuryError(
                Ret.MSGSIZE, f"{kind} message {n}B exceeds {self.name} "
                             f"limit {limit}B (use bulk RMA)")
        return n

    @staticmethod
    def as_view(buf) -> memoryview:
        if isinstance(buf, np.ndarray):
            if not buf.flags["C_CONTIGUOUS"]:
                raise MercuryError(Ret.INVALID_ARG, "buffer must be C-contiguous")
            return memoryview(buf).cast("B")
        return memoryview(buf).cast("B")
