from .base import (EXPECTED_MSG_LIMIT, NAAddress, NACallback, NACap,
                   NAMemHandle, NAOp, NAPlugin, SCHEME_TIERS, TIER_NET,
                   TIER_SELF, TIER_SM, UNEXPECTED_MSG_LIMIT)
from .multi import MultiPlugin, parse_addr_set
from .registry import initialize, register_plugin
from .self_plugin import SelfPlugin
from .sm import SMPlugin
from .tcp import TCPPlugin

__all__ = [
    "NAAddress", "NACallback", "NACap", "NAMemHandle", "NAOp", "NAPlugin",
    "UNEXPECTED_MSG_LIMIT", "EXPECTED_MSG_LIMIT", "SCHEME_TIERS",
    "TIER_SELF", "TIER_SM", "TIER_NET", "initialize", "register_plugin",
    "parse_addr_set", "SelfPlugin", "SMPlugin", "TCPPlugin", "MultiPlugin",
]
