from .base import (NAAddress, NACallback, NAMemHandle, NAOp, NAPlugin,
                   UNEXPECTED_MSG_LIMIT)
from .registry import initialize, register_plugin
from .self_plugin import SelfPlugin
from .tcp import TCPPlugin

__all__ = [
    "NAAddress", "NACallback", "NAMemHandle", "NAOp", "NAPlugin",
    "UNEXPECTED_MSG_LIMIT", "initialize", "register_plugin",
    "SelfPlugin", "TCPPlugin",
]
