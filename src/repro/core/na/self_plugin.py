"""``self`` NA plugin — in-process loopback transport.

Several plugin *instances* may coexist in one process, each with its own
URI (``self://name``); this lets tests and benchmarks stand up multi-node
service topologies (origin + several targets) without sockets. Message
delivery is a queue append; RMA put/get is a memcpy against the peer's
registered-memory table. Semantics (unexpected vs expected matching,
completion via callbacks inside ``progress()``) are identical to the tcp
plugin so upper layers cannot tell the difference — that interchangeability
is the point of the NA abstraction.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..types import MercuryError, Ret
from .base import (NAAddress, NACallback, NACap, NAMemHandle, NAOp, NAPlugin,
                   TIER_SELF)

_REGISTRY: Dict[str, "SelfPlugin"] = {}  #: guarded-by _REGISTRY_LOCK
_REGISTRY_LOCK = threading.Lock()
_ANON = [0]


class SelfAddress(NAAddress):
    def __init__(self, uri: str):
        self.uri = uri


class SelfPlugin(NAPlugin):
    name = "self"
    caps = NACap.NATIVE_RMA | NACap.ZERO_COPY | NACap.SAME_PROCESS
    tier = TIER_SELF
    max_expected_size = 1 << 62          # a memcpy: no framing limit

    def __init__(self, uri: Optional[str] = None):
        super().__init__()
        with _REGISTRY_LOCK:
            if uri is None:
                _ANON[0] += 1
                uri = f"self://node{_ANON[0]}"
            if not uri.startswith("self://"):
                uri = "self://" + uri
            if uri in _REGISTRY:
                raise MercuryError(Ret.INVALID_ARG, f"uri in use: {uri}")
            _REGISTRY[uri] = self
        self._uri = uri
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        # inbound queues (written by peers, drained by our progress())
        self._in_unexpected: Deque[Tuple[str, int, bytes, NAOp, "SelfPlugin"]] = deque()  #: guarded-by _lock,_wakeup
        self._in_expected: Deque[Tuple[str, int, bytes, NAOp, "SelfPlugin"]] = deque()  #: guarded-by _lock,_wakeup
        # posted receives
        self._recv_unexpected: Deque[Tuple[NAOp, NACallback]] = deque()  #: guarded-by _lock,_wakeup
        self._recv_expected: List[Tuple[NAOp, Optional[str], int, NACallback]] = []  #: guarded-by _lock,_wakeup
        # local completions to fire on next progress() (send/rma ops)
        self._completions: Deque[Tuple[NAOp, NACallback, Tuple]] = deque()  #: guarded-by _lock,_wakeup
        self._mem: Dict[int, memoryview] = {}  #: guarded-by _lock,_wakeup
        self._finalized = False

    # -- addressing ----------------------------------------------------------
    def addr_self(self) -> NAAddress:
        return SelfAddress(self._uri)

    def local_uris(self):
        return [self._uri]

    def addr_lookup(self, uri: str) -> NAAddress:
        if not uri.startswith("self://"):
            raise MercuryError(Ret.INVALID_ARG, f"not a self uri: {uri}")
        # reachability probe: the peer must live in this process (this is
        # what lets tiered resolution fall through to sm/tcp)
        with _REGISTRY_LOCK:
            inst = _REGISTRY.get(uri)
        if inst is None or inst._finalized:
            raise MercuryError(Ret.DISCONNECT, f"no in-process peer at {uri}")
        return SelfAddress(uri)

    @staticmethod
    def _resolve(addr: NAAddress) -> "SelfPlugin":
        with _REGISTRY_LOCK:
            inst = _REGISTRY.get(addr.uri)
        if inst is None or inst._finalized:
            raise MercuryError(Ret.DISCONNECT, f"no listener at {addr.uri}")
        return inst

    # -- messaging -----------------------------------------------------------
    def msg_send_unexpected(self, dest, data, tag, cb) -> NAOp:
        self._check_msg_size(data, self.max_unexpected_size, "unexpected")
        op = self._new_op("send_unexpected")
        peer = self._resolve(dest)
        with peer._lock:
            flat = b"".join(data) if isinstance(data, tuple) else bytes(data)
            peer._in_unexpected.append((self._uri, tag, flat, op, self))
            peer._wakeup.notify_all()
        self._complete_later(op, cb, (Ret.SUCCESS,))
        return op

    def msg_recv_unexpected(self, cb) -> NAOp:
        op = self._new_op("recv_unexpected")
        with self._lock:
            self._recv_unexpected.append((op, cb))
            self._wakeup.notify_all()
        return op

    def msg_send_expected(self, dest, data, tag, cb) -> NAOp:
        self._check_msg_size(data, self.max_expected_size, "expected")
        op = self._new_op("send_expected")
        peer = self._resolve(dest)
        with peer._lock:
            flat = b"".join(data) if isinstance(data, tuple) else bytes(data)
            peer._in_expected.append((self._uri, tag, flat, op, self))
            peer._wakeup.notify_all()
        self._complete_later(op, cb, (Ret.SUCCESS,))
        return op

    def msg_recv_expected(self, source, tag, cb) -> NAOp:
        op = self._new_op("recv_expected")
        src = source.uri if source is not None else None
        with self._lock:
            self._recv_expected.append((op, src, tag, cb))
            self._wakeup.notify_all()
        return op

    # -- RMA -----------------------------------------------------------------
    def mem_register(self, buf, read=True, write=True, key=None) -> NAMemHandle:
        view = self.as_view(buf)
        key = key if key is not None else self._mem_counter.next()
        with self._lock:
            self._mem[key] = view
        return NAMemHandle(key=key, size=view.nbytes, owner_uri=self._uri,
                           read_allowed=read, write_allowed=write,
                           local_buf=view)

    def mem_deregister(self, mh: NAMemHandle) -> None:
        with self._lock:
            self._mem.pop(mh.key, None)

    def _peer_mem(self, dest: NAAddress, remote: NAMemHandle) -> memoryview:
        peer = self._resolve(dest)
        with peer._lock:
            view = peer._mem.get(remote.key)
        if view is None:
            raise MercuryError(Ret.PERMISSION, f"mem key {remote.key} not registered at {dest.uri}")
        return view

    def put(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        op = self._new_op("put")
        if not remote.write_allowed:
            raise MercuryError(Ret.PERMISSION, "remote handle is read-only")
        rview = self._peer_mem(dest, remote)
        if remote_off + size > rview.nbytes or local_off + size > local.local_buf.nbytes:
            raise MercuryError(Ret.INVALID_ARG, "RMA put out of bounds")
        rview[remote_off:remote_off + size] = local.local_buf[local_off:local_off + size]
        self._complete_later(op, cb, (Ret.SUCCESS,))
        return op

    def get(self, local, local_off, dest, remote, remote_off, size, cb) -> NAOp:
        op = self._new_op("get")
        if not remote.read_allowed:
            raise MercuryError(Ret.PERMISSION, "remote handle is write-only")
        rview = self._peer_mem(dest, remote)
        if remote_off + size > rview.nbytes or local_off + size > local.local_buf.nbytes:
            raise MercuryError(Ret.INVALID_ARG, "RMA get out of bounds")
        local.local_buf[local_off:local_off + size] = rview[remote_off:remote_off + size]
        self._complete_later(op, cb, (Ret.SUCCESS,))
        return op

    # -- progress ------------------------------------------------------------
    def _complete_later(self, op: NAOp, cb: NACallback, args: Tuple) -> None:
        with self._lock:
            self._completions.append((op, cb, args))
            self._wakeup.notify_all()

    def _match_expected_locked(self):
        """Match queued expected messages against posted receives."""
        fired = []
        if not self._in_expected:
            return fired
        remaining = deque()
        while self._in_expected:
            src, tag, data, send_op, sender = self._in_expected.popleft()
            hit = None
            for i, (op, want_src, want_tag, cb) in enumerate(self._recv_expected):
                if op.canceled:
                    continue
                if want_tag == tag and (want_src is None or want_src == src):
                    hit = i
                    break
            if hit is None:
                remaining.append((src, tag, data, send_op, sender))
            else:
                op, _, _, cb = self._recv_expected.pop(hit)
                op.done = True
                fired.append((cb, (Ret.SUCCESS, memoryview(data))))
        self._in_expected = remaining
        return fired

    def progress(self, timeout: float) -> bool:
        fired = []
        with self._lock:
            # purge canceled posted receives
            self._recv_expected = [r for r in self._recv_expected if not r[0].canceled]
            while self._recv_unexpected and self._recv_unexpected[0][0].canceled:
                self._recv_unexpected.popleft()

            def harvest_locked():
                out = []
                while self._completions:
                    op, cb, args = self._completions.popleft()
                    if not op.canceled:
                        op.done = True
                        out.append((cb, args))
                while self._in_unexpected and self._recv_unexpected:
                    op, cb = self._recv_unexpected.popleft()
                    if op.canceled:
                        continue
                    src, tag, data, send_op, sender = self._in_unexpected.popleft()
                    op.done = True
                    out.append((cb, (Ret.SUCCESS, SelfAddress(src), tag, memoryview(data))))
                out.extend(self._match_expected_locked())
                return out

            fired = harvest_locked()
            if not fired and timeout > 0:
                self._wakeup.wait(timeout)
                fired = harvest_locked()

        for cb, args in fired:
            cb(*args)
        return bool(fired)

    def interrupt(self) -> None:
        with self._lock:
            self._wakeup.notify_all()

    def finalize(self) -> None:
        self._finalized = True
        with _REGISTRY_LOCK:
            _REGISTRY.pop(self._uri, None)
        self.interrupt()
