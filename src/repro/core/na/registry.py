"""Plugin registry — ``na_initialize("tcp://...")`` equivalent."""
from __future__ import annotations

from typing import Optional

from ..types import MercuryError, Ret
from .base import NAPlugin
from .self_plugin import SelfPlugin
from .tcp import TCPPlugin

_PLUGINS = {
    "self": SelfPlugin,
    "tcp": TCPPlugin,
}


def register_plugin(scheme: str, cls) -> None:
    _PLUGINS[scheme] = cls


def initialize(uri: Optional[str] = None, listen: bool = True) -> NAPlugin:
    """Create a plugin instance from a URI scheme.

    ``initialize("self://svc1")``, ``initialize("tcp://127.0.0.1:0")``,
    ``initialize("tcp")`` (ephemeral port), ``initialize()`` (self, anon).
    """
    if uri is None:
        return SelfPlugin()
    scheme = uri.split("://", 1)[0] if "://" in uri else uri
    cls = _PLUGINS.get(scheme)
    if cls is None:
        raise MercuryError(Ret.INVALID_ARG, f"unknown NA plugin: {scheme}")
    if "://" not in uri:
        uri = None
    if cls is TCPPlugin:
        return cls(uri, listen=listen)
    return cls(uri)
