"""Plugin registry — ``na_initialize("tcp://...")`` equivalent.

URI-scheme dispatch plus multi-transport initialization: a semicolon-
joined URI (or a list of URIs) stands up one plugin per scheme wrapped in
:class:`MultiPlugin`, which resolves target address sets to the cheapest
reachable tier (self > sm > tcp — see DESIGN.md §2).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

from ..types import MercuryError, Ret
from .base import NAPlugin
from .multi import MultiPlugin, parse_addr_set
from .self_plugin import SelfPlugin
from .sm import SMPlugin
from .tcp import TCPPlugin

_PLUGINS = {
    "self": SelfPlugin,
    "sm": SMPlugin,
    "tcp": TCPPlugin,
}


def register_plugin(scheme: str, cls) -> None:
    _PLUGINS[scheme] = cls


def _initialize_one(uri: str, listen: bool) -> NAPlugin:
    scheme = uri.split("://", 1)[0] if "://" in uri else uri
    cls = _PLUGINS.get(scheme)
    if cls is None:
        raise MercuryError(Ret.INVALID_ARG, f"unknown NA plugin: {scheme}")
    if "://" not in uri:
        uri = None
    if cls is TCPPlugin:
        return cls(uri, listen=listen)
    return cls(uri)


def initialize(uri: Union[str, Sequence[str], None] = None,
               listen: bool = True) -> NAPlugin:
    """Create a plugin instance (or a tiered multi-transport stack).

    ``initialize("self://svc1")``, ``initialize("sm://svc1")``,
    ``initialize("tcp://127.0.0.1:0")``, ``initialize("tcp")`` (ephemeral
    port), ``initialize()`` (self, anon), and
    ``initialize("self://a;sm://a;tcp://127.0.0.1:0")`` or
    ``initialize(["sm://a", "tcp://127.0.0.1:0"])`` (multi-transport).
    """
    if uri is None:
        return SelfPlugin()
    uris: List[str] = list(uri) if not isinstance(uri, str) \
        else parse_addr_set(uri)
    if not uris:
        raise MercuryError(Ret.INVALID_ARG, f"empty NA uri: {uri!r}")
    if len(uris) == 1:
        return _initialize_one(uris[0], listen)
    return MultiPlugin([_initialize_one(u, listen) for u in uris])
