"""Mercury-style RPC core (the paper's contribution), in Python/JAX-land.

Layering (bottom-up), mirroring the paper's Figure 1:

    na/         network abstraction layer (plugins: self, tcp)
    proc.py     argument serialization (hg_proc)
    rpc.py      RPC operation layer (register/forward/respond)
    bulk.py     large-data transfers (descriptors + one-sided pipelined RMA)
    progress.py completion queue + progress/trigger
    executor.py request-model & multithreaded shims (built ON TOP, per paper)
"""
from .bulk import (BulkDescriptor, BulkHandle, BulkOp, BulkOpType,
                   bulk_transfer, expose_arrays)
from .executor import Engine, RemoteError
from .progress import Context
from .rpc import Handle, HGClass
from .types import CallbackInfo, Flags, MercuryError, OpType, Ret

__all__ = [
    "BulkDescriptor", "BulkHandle", "BulkOp", "BulkOpType", "bulk_transfer",
    "expose_arrays", "Engine", "RemoteError", "Context", "Handle", "HGClass",
    "CallbackInfo", "Flags", "MercuryError", "OpType", "Ret",
]
