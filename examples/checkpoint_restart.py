"""Checkpoint/restart through the Mercury checkpoint service — the
fault-tolerance core path:

  phase 1: trainer A trains 6 steps, async-saving every 3 through the
           bulk-transfer checkpoint service (tcp);
  "crash":  trainer A is discarded entirely;
  phase 2: trainer B (fresh process state) restores the latest
           checkpoint and continues — verifying step counter and loss
           continuity.

    PYTHONPATH=src python examples/checkpoint_restart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ParallelConfig
from repro.core.executor import Engine
from repro.models import Model
from repro.services import CheckpointClient, CheckpointServer
from repro.train import optim
from repro.train.step import init_state, make_train_step

CFG = configs.reduced("gemma3-12b")


def make_batch(step):
    k = jax.random.PRNGKey(step)
    toks = jax.random.randint(k, (4, 65), 0, CFG.vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def main():
    model = Model(CFG)
    ocfg = optim.OptConfig(lr=2e-3, warmup=2, decay_steps=50)
    step = jax.jit(make_train_step(model, ocfg,
                                   ParallelConfig(remat="none")))

    ckpt_server = Engine("tcp://127.0.0.1:0")
    CheckpointServer(ckpt_server)
    print(f"[ckpt] server at {ckpt_server.uri}")

    # ---- phase 1: trainer A -------------------------------------------
    with Engine("tcp://127.0.0.1:0") as a_engine:
        ckpt_a = CheckpointClient(a_engine, ckpt_server.uri)
        state, _ = init_state(model, ocfg, jax.random.PRNGKey(0))
        pending = None
        for i in range(6):
            state, metrics = step(state, make_batch(i))
            print(f"[A] step {i} loss={float(metrics['loss']):.4f}")
            if (i + 1) % 3 == 0:
                if pending:
                    pending.result(timeout=60)
                snap = jax.tree_util.tree_map(np.asarray, state)
                pending = ckpt_a.async_save(CFG.name, i + 1, snap)
                print(f"[A] async checkpoint @ step {i + 1} submitted")
        pending.result(timeout=60)
    print("[A] 'crashed' (engine shut down, state dropped)")

    # ---- phase 2: trainer B -------------------------------------------
    with Engine("tcp://127.0.0.1:0") as b_engine:
        ckpt_b = CheckpointClient(b_engine, ckpt_server.uri)
        fresh, _ = init_state(model, ocfg, jax.random.PRNGKey(99))
        state, at = ckpt_b.restore(CFG.name, fresh)
        state = jax.tree_util.tree_map(jnp.asarray, state)
        print(f"[B] restored checkpoint @ step {at}; continuing")
        for i in range(at, at + 4):
            state, metrics = step(state, make_batch(i))
            print(f"[B] step {i} loss={float(metrics['loss']):.4f}")
        print(f"[B] available checkpoints: {ckpt_b.list()}")

    ckpt_server.shutdown()
    print("OK: restart continued from the service-held state")


if __name__ == "__main__":
    main()
