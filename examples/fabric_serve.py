"""Service fabric end-to-end: one client drives THREE gateway replicas
through a registry-backed ServicePool — locality-tiered routing (sm
where reachable, tcp otherwise), least-loaded balancing from piggybacked
stats, credit-based flow control, and mid-run failover: one replica is
killed abruptly while requests are in flight; the registry's TTL sweep
bumps the epoch, the pool reroutes, and the client sees every request
complete (budgeted retries absorb the loss).

    PYTHONPATH=src python examples/fabric_serve.py
"""
import sys
import time
import uuid

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.fabric import RegistryService, RetryPolicy, ServicePool
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway

N_REPLICAS = 3
N_REQUESTS = 12
MAX_NEW = 8


def main():
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tag = uuid.uuid4().hex[:6]

    # ---- control plane ---------------------------------------------------
    reg_engine = Engine("tcp://127.0.0.1:0")
    registry = RegistryService(reg_engine, instance_ttl=1.5,
                               sweep_interval=0.25)
    print(f"[registry] {reg_engine.uri}")

    # ---- three gateway replicas (sm+tcp address sets: a co-located
    # client resolves the cheap shared-memory tier) ------------------------
    replicas = []
    for i in range(N_REPLICAS):
        eng = Engine([f"sm://fab-rep{i}-{tag}", "tcp://127.0.0.1:0"])
        serve = ServeEngine(model, params, max_len=64, n_slots=2)
        gw = ServingGateway(eng, serve, registry=reg_engine.uri,
                            service="gen", report_interval=0.25)
        replicas.append((eng, gw))
        print(f"[replica {i}] {eng.uri}")

    # ---- client ----------------------------------------------------------
    rng = np.random.default_rng(0)
    with Engine([f"sm://fab-cli-{tag}", "tcp://127.0.0.1:0"]) as client:
        pool = ServicePool(client, reg_engine.uri, "gen",
                           balancer="locality",
                           policy=RetryPolicy(attempts=4, rpc_timeout=60.0,
                                              backoff_base=0.05),
                           refresh_interval=0.2)
        print(f"[client] pool sees {len(pool.replicas())} replicas, "
              f"tiers {[r.stat()['tier'] for r in pool.replicas()]}")

        t0 = time.time()
        rids = []          # rid is replica-local state: remember the
        for i in range(N_REQUESTS):    # serving instance for the follow-up
            prompt = rng.integers(1, cfg.vocab, size=4 + i % 3).tolist()
            out, iid = pool.call_routed(
                "gen.submit", {"tokens": prompt, "max_new": MAX_NEW,
                               "temperature": 0.7}, timeout=60.0)
            rids.append((out["rid"], iid))
            if i == N_REQUESTS // 2:
                # abrupt kill: no deregistration, heartbeats just stop —
                # the registry TTL-expires the instance (epoch bump) and
                # in-flight work reroutes through retries
                eng, gw = replicas.pop(0)
                epoch_before = pool.epoch
                gw.instance.close(deregister=False)
                gw.stop()
                eng.shutdown()
                print(f"[chaos] killed replica 0 mid-run "
                      f"(epoch was {epoch_before})")

        # gen.result is pinned to the replica that admitted the submit
        # (call_on); rids whose replica died are resubmitted — what a real
        # client of an at-most-once submit API does.
        done = 0
        for i, (rid, iid) in enumerate(rids):
            try:
                out = pool.call_on(iid, "gen.result",
                                   {"rid": rid, "wait": True,
                                    "timeout": 60.0}, timeout=90.0)
            except Exception:
                out = None             # replica (and its rids) died
            if not out or not out.get("done"):
                prompt = rng.integers(1, cfg.vocab, size=5).tolist()
                out = pool.call("gen.generate",
                                {"tokens": prompt, "max_new": MAX_NEW},
                                timeout=90.0)
            assert out["done"] and len(out["tokens"]) == MAX_NEW, out
            done += 1
        dt = time.time() - t0

        pool.refresh(force=True)
        stats = pool.stats()
        print(f"[client] {done}/{N_REQUESTS} requests completed "
              f"({done * MAX_NEW} tokens in {dt:.1f}s) — no client-visible "
              f"failure across the kill (epoch now {stats['epoch']})")
        print(f"[client] surviving replicas: {len(stats['replicas'])}")
        for r in stats["replicas"]:
            print(f"   {r['iid'][:8]} tier={r['tier']} calls={r['calls']} "
                  f"errors={r['errors']} load={r['load']:.0f} "
                  f"ema={r['ema_latency_ms']:.0f}ms")
        assert len(stats["replicas"]) == N_REPLICAS - 1

    for eng, gw in replicas:
        gw.stop()
        eng.shutdown()
    registry.close()
    reg_engine.shutdown()
    print("[fabric_serve] OK")


if __name__ == "__main__":
    main()
