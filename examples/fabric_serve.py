"""Service fabric end-to-end: one client drives THREE gateway replicas
through a ServicePool backed by a THREE-replica registry quorum.  Four
acts (see examples/README.md for the walkthrough):

**Act one — steady state + replica kill**: locality-tiered routing (sm
where reachable, tcp otherwise), least-loaded balancing from piggybacked
stats, credit-based flow control, and mid-run failover: one gateway is
killed abruptly while requests are in flight; the registry's TTL sweep
bumps the epoch, the pool reroutes, and the client sees every request
complete (budgeted retries absorb the loss).

**Act two — overload shed**: the surviving replicas are flooded with
more deadlined work than their slots can serve.  Deadline-aware
admission control sheds the excess with ``Ret.OVERLOAD`` *before* it
burns a slot (the pool reroutes sheds immediately — no backoff), so the
capacity that exists is spent on requests that can still meet their
deadlines instead of on a queue of doomed ones.

**Act three — registry failover**: the registry *leaseholder* is killed
abruptly.  Routed traffic keeps flowing (the pool's registry client
rotates to a surviving replica, which serves reads from its mirrored
view); after the lease expires the next-ranked replica takes over and
the pool resyncs onto its fresh epoch stream — the control plane is no
longer a single point of failure (DESIGN.md §8).

**Act four — the trace of a kill**: tracing is cranked to 100% and
*another* gateway is killed.  A generate call that retries across the
corpse leaves a distributed span tree — pool root, one attempt span per
try (the dead hop closed with its failure, the survivor ``OK``), the
server's serve spans — which is fetched back over ``dbg.trace`` and
pretty-printed: the flight recorder for every act above (DESIGN.md
§10).

    PYTHONPATH=src python examples/fabric_serve.py
"""
import concurrent.futures as cf
import sys
import threading
import time
import uuid

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.fabric import RegistryService, RetryPolicy, ServicePool
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway
from repro.telemetry import trace

N_REPLICAS = 3
N_REQUESTS = 12
MAX_NEW = 8


def main():
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tag = uuid.uuid4().hex[:6]

    # ---- control plane: a 3-replica registry quorum ----------------------
    reg_engines = [Engine("tcp://127.0.0.1:0") for _ in range(3)]
    reg_peers = [e.uri for e in reg_engines]
    registries = [RegistryService(e, peers=reg_peers, lease_ttl=0.75,
                                  gossip_interval=0.2, instance_ttl=1.5,
                                  sweep_interval=0.25)
                  for e in reg_engines]
    while not registries[0].is_leader:      # cold start: rank 0 elects
        time.sleep(0.05)                    # after one boot-grace lease
    print(f"[registry] quorum of {len(reg_peers)}, "
          f"leaseholder {reg_peers[0]}")

    # ---- three gateway replicas (sm+tcp address sets: a co-located
    # client resolves the cheap shared-memory tier).  Registration and
    # heartbeats go to the whole quorum address set and fail over. -------
    replicas = []
    for i in range(N_REPLICAS):
        eng = Engine([f"sm://fab-rep{i}-{tag}", "tcp://127.0.0.1:0"])
        serve = ServeEngine(model, params, max_len=64, n_slots=2)
        gw = ServingGateway(eng, serve, registry=",".join(reg_peers),
                            service="gen", report_interval=0.25)
        replicas.append((eng, gw))
        print(f"[replica {i}] {eng.uri}")

    # ---- client ----------------------------------------------------------
    rng = np.random.default_rng(0)
    with Engine([f"sm://fab-cli-{tag}", "tcp://127.0.0.1:0"]) as client:
        pool = ServicePool(client, reg_peers, "gen",
                           balancer="locality",
                           policy=RetryPolicy(attempts=4, rpc_timeout=60.0,
                                              backoff_base=0.05),
                           refresh_interval=0.2)
        print(f"[client] pool sees {len(pool.replicas())} replicas, "
              f"tiers {[r.stat()['tier'] for r in pool.replicas()]}")

        t0 = time.time()
        rids = []          # rid is replica-local state: remember the
        for i in range(N_REQUESTS):    # serving instance for the follow-up
            prompt = rng.integers(1, cfg.vocab, size=4 + i % 3).tolist()
            out, iid = pool.call_routed(
                "gen.submit", {"tokens": prompt, "max_new": MAX_NEW,
                               "temperature": 0.7}, timeout=60.0)
            rids.append((out["rid"], iid))
            if i == N_REQUESTS // 2:
                # abrupt kill: no deregistration, heartbeats just stop —
                # the registry TTL-expires the instance (epoch bump) and
                # in-flight work reroutes through retries
                eng, gw = replicas.pop(0)
                epoch_before = pool.epoch
                gw.instance.close(deregister=False)
                gw.stop()
                eng.shutdown()
                print(f"[chaos] killed replica 0 mid-run "
                      f"(epoch was {epoch_before})")

        # gen.result is pinned to the replica that admitted the submit
        # (call_on); rids whose replica died are resubmitted — what a real
        # client of an at-most-once submit API does.
        done = 0
        for i, (rid, iid) in enumerate(rids):
            try:
                out = pool.call_on(iid, "gen.result",
                                   {"rid": rid, "wait": True,
                                    "timeout": 60.0}, timeout=90.0)
            except Exception:
                out = None             # replica (and its rids) died
            if not out or not out.get("done"):
                prompt = rng.integers(1, cfg.vocab, size=5).tolist()
                out = pool.call("gen.generate",
                                {"tokens": prompt, "max_new": MAX_NEW},
                                timeout=90.0)
            assert out["done"] and len(out["tokens"]) == MAX_NEW, out
            done += 1
        dt = time.time() - t0

        pool.refresh(force=True)
        stats = pool.stats()
        print(f"[client] {done}/{N_REQUESTS} requests completed "
              f"({done * MAX_NEW} tokens in {dt:.1f}s) — no client-visible "
              f"failure across the kill (epoch now {stats['epoch']})")
        print(f"[client] surviving replicas: {len(stats['replicas'])}")
        for r in stats["replicas"]:
            print(f"   {r['iid'][:8]} tier={r['tier']} calls={r['calls']} "
                  f"errors={r['errors']} load={r['load']:.0f} "
                  f"ema={r['ema_latency_ms']:.0f}ms "
                  f"credits={r['credits']}")
        assert len(stats["replicas"]) == N_REPLICAS - 1

        # ---- act two: overload + deadline-aware admission ----------------
        # calibrate first: act one's latencies are JIT-compile-dominated,
        # so run sequential warm requests until the admission EWMA
        # reflects steady-state service time, and measure it ourselves
        cal = []
        for _ in range(50):
            t0 = time.time()
            pool.call("gen.generate",
                      {"tokens": rng.integers(1, cfg.vocab,
                                              size=4).tolist(),
                       "max_new": MAX_NEW}, timeout=60.0)
            cal.append(time.time() - t0)
        svc_s = sorted(cal)[len(cal) // 2]

        # flood the two survivors (2 slots each) with deadlined work well
        # beyond the drain rate.  The budget must clear the *servers'*
        # believed service time (their admission EWMA — pure
        # slot-occupancy time, so no queue-wait inflation) by ~2x so an
        # empty-queue request is admitted, but stay far below the time
        # the full flood needs to drain, so anything behind a deep queue
        # is shed before it burns a slot; the svc term + fixed allowance
        # covers client-side fan-out overhead
        emas = [s["ema_service_ms"] / 1e3
                for s in pool.call_each("gen.stats", timeout=30.0).values()
                if isinstance(s, dict)]
        ema_s = max(emas) if emas else svc_s
        deadline_s = max(svc_s * 3.0, ema_s * 2.0) + 0.1
        n_flood = 48
        print(f"[overload] flooding {n_flood} requests, deadline "
              f"{deadline_s * 1e3:.0f}ms (measured service "
              f"{svc_s * 1e3:.0f}ms, admission ema {ema_s * 1e3:.0f}ms)")

        def one(i):
            t0 = time.time()
            try:
                out = pool.call("gen.generate",
                                {"tokens": rng.integers(
                                    1, cfg.vocab, size=4).tolist(),
                                 "max_new": MAX_NEW,
                                 "timeout": deadline_s},
                                timeout=deadline_s)
                return ("ok" if out["done"] else "late",
                        time.time() - t0)
            except Exception:     # shed everywhere / backpressured out
                return ("miss", time.time() - t0)

        t0 = time.time()
        with cf.ThreadPoolExecutor(n_flood) as tp:
            results = list(tp.map(one, range(n_flood)))
        flood_dt = time.time() - t0
        ok = sum(1 for s, _ in results if s == "ok")
        miss = sum(1 for s, _ in results if s == "miss")
        miss_lat = sorted(l for s, l in results if s == "miss")
        stats = pool.call_each("gen.stats", timeout=10.0)
        server_shed = sum(s["shed"] for s in stats.values()
                          if isinstance(s, dict))
        print(f"[overload] {ok} completed in-deadline, {miss} "
              f"shed/missed ({server_shed} server-side OVERLOAD sheds) "
              f"in {flood_dt:.1f}s"
              + (f"; misses resolved at median "
                 f"{miss_lat[len(miss_lat) // 2] * 1e3:.0f}ms — "
                 f"no doomed request held a slot" if miss_lat
                 else " (machine outran the flood)"))
        # the point of admission control: the flood resolves fast — work
        # either completed in-deadline, was shed server-side before
        # burning a slot, or was backpressured at the client's credit
        # gates; nothing parked on a queue it couldn't survive
        gate_rejects = sum(r.get("rejected", 0)
                           for r in pool.stats()["replicas"])
        assert ok >= 1 or server_shed >= 1 or gate_rejects >= 1
        assert not miss_lat or miss_lat[-1] < deadline_s * 3

        # ---- act three: registry failover --------------------------------
        # kill the leaseholder abruptly: no goodbye, its peers learn via
        # lease expiry.  Routed traffic must keep flowing throughout —
        # the pool's registry client rotates to a surviving replica,
        # which serves resolution from its gossip-mirrored view.
        leader_idx = next(i for i, r in enumerate(registries)
                          if r.is_leader)
        registries[leader_idx].close()
        reg_engines[leader_idx].shutdown()
        t_kill = time.monotonic()
        print(f"[chaos] killed registry leaseholder "
              f"{reg_peers[leader_idx]}")
        survivors = [r for i, r in enumerate(registries)
                     if i != leader_idx]
        takeover = {}

        def watch_lease():                 # timestamp the lease handoff
            while not any(r.is_leader for r in survivors):
                time.sleep(0.02)
            takeover["ms"] = (time.monotonic() - t_kill) * 1e3

        watcher = threading.Thread(target=watch_lease)
        watcher.start()
        fails = 0
        for i in range(8):                 # through kill + takeover
            try:
                out = pool.call("gen.generate",
                                {"tokens": rng.integers(
                                    1, cfg.vocab, size=4).tolist(),
                                 "max_new": MAX_NEW}, timeout=60.0)
                assert out["done"]
            except Exception:
                fails += 1
            time.sleep(0.15)
        watcher.join()
        takeover_ms = takeover["ms"]
        new_leader = next(r for r in survivors if r.is_leader)
        pool.refresh(force=True)
        status = pool.registry.status()
        print(f"[registry] lease moved to {new_leader.self_uri} in "
              f"{takeover_ms:.0f}ms (new epoch stream "
              f"{new_leader.nonce[:6]}…); pool resolved via "
              f"{status['self']} ({status['role']})")
        print(f"[client] {8 - fails}/8 requests completed across the "
              f"control-plane kill ({fails} failures)")
        assert fails == 0, "registry failover must be client-invisible"
        assert len(pool.replicas()) == N_REPLICAS - 1   # view survived

        # ---- act four: the trace of a kill -------------------------------
        # 100% sampling, then kill another gateway without deregistering:
        # until the TTL sweep evicts it, the pool still routes to the
        # corpse, fails fast, and retries — and with tracing on, that
        # whole story is a span tree any engine will hand back over
        # dbg.trace.  No collector, no sidecar: the rings are the store.
        trace.configure(sample=1.0, enabled=True)
        eng4, gw4 = replicas.pop(0)
        gw4.instance.close(deregister=False)
        gw4.stop()
        eng4.shutdown()
        print("[chaos] killed another gateway, tracing at 100%")
        picked = None
        for _ in range(24):            # catch a call that had to retry
            trace.clear()
            out = pool.call("gen.generate",
                            {"tokens": rng.integers(1, cfg.vocab,
                                                    size=4).tolist(),
                             "max_new": MAX_NEW}, timeout=60.0)
            assert out["done"]
            ring = trace.export()["spans"]
            picked = next((s for s in ring
                           if s["name"].startswith("pool.gen.")
                           and s["parent"] is None), picked)
            if picked and picked["tags"].get("attempts", 1) >= 2:
                break
        assert picked is not None
        tid = picked["trace"]

        # reassemble: our own ring plus dbg.trace from every survivor —
        # in this demo all engines share one process (one ring), but the
        # fetch path is the same RPC a real debugger uses fleet-wide
        spans = {s["span"]: s for s in trace.spans_for(tid)}
        for r_eng, _gw in replicas:
            got = client.call(r_eng.uri, "dbg.trace", {"trace_id": tid},
                              timeout=10.0)
            for s in got["spans"]:
                spans.setdefault(s["span"], s)
        roots, _kids = trace.build_tree(list(spans.values()))
        n_att = picked["tags"].get("attempts", 1)
        print(f"[trace] generate call {tid[:8]}… — {len(spans)} spans, "
              f"{n_att} attempt(s), one connected tree:")
        for line in trace.format_tree(list(spans.values())).splitlines():
            print(f"   {line}")
        assert len(roots) == 1, "a hop dropped trace context"

    for eng, gw in replicas:
        gw.stop()
        eng.shutdown()
    for i, r in enumerate(registries):
        if i != leader_idx:
            r.close()
            reg_engines[i].shutdown()
    print("[fabric_serve] OK")


if __name__ == "__main__":
    main()
