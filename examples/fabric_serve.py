"""Service fabric end-to-end: one client drives THREE gateway replicas
through a registry-backed ServicePool — locality-tiered routing (sm
where reachable, tcp otherwise), least-loaded balancing from piggybacked
stats, credit-based flow control, and mid-run failover: one replica is
killed abruptly while requests are in flight; the registry's TTL sweep
bumps the epoch, the pool reroutes, and the client sees every request
complete (budgeted retries absorb the loss).

Act two is an **overload scenario**: the surviving replicas are flooded
with more deadlined work than their slots can serve.  Deadline-aware
admission control sheds the excess with ``Ret.OVERLOAD`` *before* it
burns a slot (the pool reroutes sheds immediately — no backoff), so the
capacity that exists is spent on requests that can still meet their
deadlines instead of on a queue of doomed ones.

    PYTHONPATH=src python examples/fabric_serve.py
"""
import concurrent.futures as cf
import sys
import time
import uuid

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.fabric import RegistryService, RetryPolicy, ServicePool
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway

N_REPLICAS = 3
N_REQUESTS = 12
MAX_NEW = 8


def main():
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    tag = uuid.uuid4().hex[:6]

    # ---- control plane ---------------------------------------------------
    reg_engine = Engine("tcp://127.0.0.1:0")
    registry = RegistryService(reg_engine, instance_ttl=1.5,
                               sweep_interval=0.25)
    print(f"[registry] {reg_engine.uri}")

    # ---- three gateway replicas (sm+tcp address sets: a co-located
    # client resolves the cheap shared-memory tier) ------------------------
    replicas = []
    for i in range(N_REPLICAS):
        eng = Engine([f"sm://fab-rep{i}-{tag}", "tcp://127.0.0.1:0"])
        serve = ServeEngine(model, params, max_len=64, n_slots=2)
        gw = ServingGateway(eng, serve, registry=reg_engine.uri,
                            service="gen", report_interval=0.25)
        replicas.append((eng, gw))
        print(f"[replica {i}] {eng.uri}")

    # ---- client ----------------------------------------------------------
    rng = np.random.default_rng(0)
    with Engine([f"sm://fab-cli-{tag}", "tcp://127.0.0.1:0"]) as client:
        pool = ServicePool(client, reg_engine.uri, "gen",
                           balancer="locality",
                           policy=RetryPolicy(attempts=4, rpc_timeout=60.0,
                                              backoff_base=0.05),
                           refresh_interval=0.2)
        print(f"[client] pool sees {len(pool.replicas())} replicas, "
              f"tiers {[r.stat()['tier'] for r in pool.replicas()]}")

        t0 = time.time()
        rids = []          # rid is replica-local state: remember the
        for i in range(N_REQUESTS):    # serving instance for the follow-up
            prompt = rng.integers(1, cfg.vocab, size=4 + i % 3).tolist()
            out, iid = pool.call_routed(
                "gen.submit", {"tokens": prompt, "max_new": MAX_NEW,
                               "temperature": 0.7}, timeout=60.0)
            rids.append((out["rid"], iid))
            if i == N_REQUESTS // 2:
                # abrupt kill: no deregistration, heartbeats just stop —
                # the registry TTL-expires the instance (epoch bump) and
                # in-flight work reroutes through retries
                eng, gw = replicas.pop(0)
                epoch_before = pool.epoch
                gw.instance.close(deregister=False)
                gw.stop()
                eng.shutdown()
                print(f"[chaos] killed replica 0 mid-run "
                      f"(epoch was {epoch_before})")

        # gen.result is pinned to the replica that admitted the submit
        # (call_on); rids whose replica died are resubmitted — what a real
        # client of an at-most-once submit API does.
        done = 0
        for i, (rid, iid) in enumerate(rids):
            try:
                out = pool.call_on(iid, "gen.result",
                                   {"rid": rid, "wait": True,
                                    "timeout": 60.0}, timeout=90.0)
            except Exception:
                out = None             # replica (and its rids) died
            if not out or not out.get("done"):
                prompt = rng.integers(1, cfg.vocab, size=5).tolist()
                out = pool.call("gen.generate",
                                {"tokens": prompt, "max_new": MAX_NEW},
                                timeout=90.0)
            assert out["done"] and len(out["tokens"]) == MAX_NEW, out
            done += 1
        dt = time.time() - t0

        pool.refresh(force=True)
        stats = pool.stats()
        print(f"[client] {done}/{N_REQUESTS} requests completed "
              f"({done * MAX_NEW} tokens in {dt:.1f}s) — no client-visible "
              f"failure across the kill (epoch now {stats['epoch']})")
        print(f"[client] surviving replicas: {len(stats['replicas'])}")
        for r in stats["replicas"]:
            print(f"   {r['iid'][:8]} tier={r['tier']} calls={r['calls']} "
                  f"errors={r['errors']} load={r['load']:.0f} "
                  f"ema={r['ema_latency_ms']:.0f}ms "
                  f"credits={r['credits']}")
        assert len(stats["replicas"]) == N_REPLICAS - 1

        # ---- act two: overload + deadline-aware admission ----------------
        # calibrate first: act one's latencies are JIT-compile-dominated,
        # so run sequential warm requests until the admission EWMA
        # reflects steady-state service time, and measure it ourselves
        cal = []
        for _ in range(50):
            t0 = time.time()
            pool.call("gen.generate",
                      {"tokens": rng.integers(1, cfg.vocab,
                                              size=4).tolist(),
                       "max_new": MAX_NEW}, timeout=60.0)
            cal.append(time.time() - t0)
        svc_s = sorted(cal)[len(cal) // 2]

        # flood the two survivors (2 slots each) with deadlined work well
        # beyond the drain rate.  The budget must clear the *servers'*
        # believed service time (their admission EWMA — possibly still
        # decaying from the compile-heavy act one) by ~1.5x so an
        # empty-queue request is admitted, but only ~1.5x, so anything
        # behind a queue is shed before it burns a slot; the svc term +
        # fixed allowance covers client-side fan-out overhead
        emas = [s["ema_service_ms"] / 1e3
                for s in pool.call_each("gen.stats", timeout=30.0).values()
                if isinstance(s, dict)]
        ema_s = max(emas) if emas else svc_s
        deadline_s = max(svc_s * 2.5, ema_s * 1.5) + 0.1
        n_flood = 48
        print(f"[overload] flooding {n_flood} requests, deadline "
              f"{deadline_s * 1e3:.0f}ms (measured service "
              f"{svc_s * 1e3:.0f}ms, admission ema {ema_s * 1e3:.0f}ms)")

        def one(i):
            t0 = time.time()
            try:
                out = pool.call("gen.generate",
                                {"tokens": rng.integers(
                                    1, cfg.vocab, size=4).tolist(),
                                 "max_new": MAX_NEW,
                                 "timeout": deadline_s},
                                timeout=deadline_s)
                return ("ok" if out["done"] else "late",
                        time.time() - t0)
            except Exception:     # shed everywhere / backpressured out
                return ("miss", time.time() - t0)

        t0 = time.time()
        with cf.ThreadPoolExecutor(n_flood) as tp:
            results = list(tp.map(one, range(n_flood)))
        flood_dt = time.time() - t0
        ok = sum(1 for s, _ in results if s == "ok")
        miss = sum(1 for s, _ in results if s == "miss")
        miss_lat = sorted(l for s, l in results if s == "miss")
        stats = pool.call_each("gen.stats", timeout=10.0)
        server_shed = sum(s["shed"] for s in stats.values()
                          if isinstance(s, dict))
        print(f"[overload] {ok} completed in-deadline, {miss} "
              f"shed/missed ({server_shed} server-side OVERLOAD sheds) "
              f"in {flood_dt:.1f}s"
              + (f"; misses resolved at median "
                 f"{miss_lat[len(miss_lat) // 2] * 1e3:.0f}ms — "
                 f"no doomed request held a slot" if miss_lat
                 else " (machine outran the flood)"))
        # the point of admission control: the flood resolves fast — work
        # either completed in-deadline or was shed/failed within ~a
        # deadline of its issue, never parked on a queue it can't survive
        assert ok >= 1 or server_shed >= 1
        assert not miss_lat or miss_lat[-1] < deadline_s * 3

    for eng, gw in replicas:
        gw.stop()
        eng.shutdown()
    registry.close()
    reg_engine.shutdown()
    print("[fabric_serve] OK")


if __name__ == "__main__":
    main()
