"""Quickstart: train a reduced model for a few steps using the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen1.5-0.5b]

Shows the three layers working together: configs → Model → train step,
with the data pipeline feeding batches.
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import configs
from repro.configs.base import ParallelConfig
from repro.data.pipeline import Prefetcher, SyntheticSource
from repro.models import Model
from repro.train import optim
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    choices=configs.names())
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.reduced(args.arch)
    print(f"arch={cfg.name} (reduced): {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} family={cfg.family}")

    model = Model(cfg)
    opt_cfg = optim.OptConfig(lr=1e-3, warmup=3, decay_steps=args.steps)
    state, axes = init_state(model, opt_cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
    print(f"params: {n_params:,}")

    step = jax.jit(make_train_step(model, opt_cfg,
                                   ParallelConfig(remat="none")))
    frontend = (cfg.frontend_seq, cfg.frontend_dim) \
        if cfg.frontend != "none" else None
    src = SyntheticSource(cfg.vocab, 64, 4, frontend=frontend)
    feed = Prefetcher(src, depth=2)

    t0 = time.time()
    for i in range(args.steps):
        raw = next(feed)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.family == "vlm":
            F = cfg.frontend_seq
            batch["targets"] = jnp.concatenate(
                [jnp.full((batch["tokens"].shape[0], F), -1, jnp.int32),
                 batch["targets"]], axis=1)
        state, metrics = step(state, batch)
        print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"gnorm {float(metrics['grad_norm']):.2f}")
    print(f"done in {time.time() - t0:.1f}s")
    feed.close()


if __name__ == "__main__":
    main()
