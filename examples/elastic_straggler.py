"""Elasticity + straggler mitigation demo:

  * three worker engines join the membership service; one stops
    heartbeating ("fails"); the survivors observe the epoch bump and
    rebuild their world view (elastic scaling signal);
  * two datafeed replicas serve batches; one is artificially slow —
    ``replicated_call`` issues to both and takes the first responder,
    so the straggler never stalls the step.

    PYTHONPATH=src python examples/elastic_straggler.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.executor import Engine
from repro.data.pipeline import SyntheticSource
from repro.services import (DataFeedServer, MembershipClient,
                            MembershipServer, replicated_call)


def main():
    # ---- membership / elasticity ---------------------------------------
    coord = Engine("tcp://127.0.0.1:0")
    MembershipServer(coord, heartbeat_timeout=0.6, sweep_interval=0.15)
    workers = [Engine("tcp://127.0.0.1:0") for _ in range(3)]
    clients = []
    for i, w in enumerate(workers):
        c = MembershipClient(
            w, coord.uri, f"worker-{i}", 0.15,
            on_change=lambda v, i=i: print(
                f"  [worker-{i}] epoch {v['epoch']}: members {v['members']}"))
        c.join({"slot": i})
        clients.append(c)
    time.sleep(0.5)
    print("[elastic] initial view:", clients[0].current_view()["members"])

    print("[elastic] worker-2 fails (heartbeat stops)…")
    clients[2]._stop.set()
    deadline = time.time() + 5
    while time.time() < deadline:
        if clients[0].current_view()["members"] == ["worker-0", "worker-1"]:
            break
        time.sleep(0.1)
    view = clients[0].current_view()
    print(f"[elastic] survivors rebuild with {view['members']} "
          f"(epoch {view['epoch']}) — driver would re-mesh + restore here")

    # ---- straggler mitigation -------------------------------------------
    src = SyntheticSource(vocab=1000, seq_len=256, batch_per_host=4)
    fast = Engine("tcp://127.0.0.1:0")
    slow = Engine("tcp://127.0.0.1:0")
    DataFeedServer(fast, src)

    class SlowSource:
        def batch_at(self, step):
            time.sleep(2.0)                  # persistent straggler
            return src.batch_at(step)

    DataFeedServer(slow, SlowSource())
    trainer = Engine("tcp://127.0.0.1:0")

    t0 = time.time()
    for s in range(3):
        rsp = replicated_call(trainer, [slow.uri, fast.uri], "feed.get",
                              {"step": s}, timeout=30.0)
        assert rsp["mode"] in ("eager", "bulk")
        print(f"[straggler] step {s} served in "
              f"{time.time() - t0:.2f}s cumulative (first-wins)")
    assert time.time() - t0 < 4.0, "straggler must not gate the steps"

    for e in [coord, fast, slow, trainer] + workers:
        e.shutdown()
    print("OK")


if __name__ == "__main__":
    main()
