"""End-to-end serving driver (the paper's kind of deployment): a model
server hosts an LM behind the Mercury gateway; a separate client engine
submits batched prompts over the tcp NA plugin and streams results.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway


def main():
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))

    # ---- server process role -------------------------------------------
    server = Engine("tcp://127.0.0.1:0")
    engine = ServeEngine(model, params, max_len=96, n_slots=4)
    gateway = ServingGateway(server, engine)
    print(f"[server] {cfg.name} listening at {server.uri}")

    # ---- client process role -------------------------------------------
    rng = np.random.default_rng(1)
    with Engine("tcp://127.0.0.1:0") as client:
        # submit a burst of 8 requests (only 4 slots: continuous batching
        # drains the queue as slots free up)
        rids = []
        t0 = time.time()
        for i in range(8):
            prompt = rng.integers(1, cfg.vocab, size=4 + i % 3).tolist()
            r = client.call(server.uri, "gen.submit",
                            {"tokens": prompt, "max_new": 10,
                             "temperature": 0.8})
            rids.append(r["rid"])
            print(f"[client] submitted rid={r['rid']} prompt={prompt}")

        for rid in rids:
            out = client.call(server.uri, "gen.result",
                              {"rid": rid, "wait": True}, timeout=300.0)
            print(f"[client] rid={rid} -> {out['tokens']}")

        stats = client.call(server.uri, "gen.stats", {})
        dt = time.time() - t0
        toks = 8 * 10
        print(f"[client] {toks} tokens in {dt:.1f}s "
              f"({toks / dt:.1f} tok/s), server stats: {stats}")

    gateway.stop()
    server.shutdown()


if __name__ == "__main__":
    main()
